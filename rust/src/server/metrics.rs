//! Lock-cheap serving metrics: atomic log-linear histograms and counters.
//!
//! [`Histogram`] records `u64` samples (latencies in microseconds, batch
//! occupancies, queue depths) into fixed log-linear buckets — 8 sub-buckets
//! per octave, ≤ 12.5% relative error — using only relaxed atomic
//! increments, so many connection workers can record concurrently with no
//! lock and no allocation. Quantiles are computed on read by a bucket
//! scan (served via `?format=json`); the text exposition renders each
//! histogram in standard Prometheus form — sparse cumulative
//! `_bucket{le=…}` series plus `_sum`/`_count` — so `histogram_quantile`
//! works server-side. [`ServeMetrics`] groups the histograms and counters
//! the serving path shares, renders them in Prometheus text format for
//! `GET /metrics` and as a human summary for shutdown.
//! [`render_metadata`] emits the one-per-family `# HELP`/`# TYPE` header
//! block and [`lint_exposition`] re-parses a full page as a self-check.

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::obs::{Stage, StageSet, TraceRing, ALL_STAGES, STAGE_COUNT};
use crate::server::admission::{ShedReason, ALL_SHED_REASONS, SHED_REASONS};
use crate::util::json::Json;

/// Process-wide boot instant behind `pgpr_process_uptime_seconds`.
/// Anchored by the first [`process_start`] call ([`Server::start_with_registry`]
/// calls it at boot); distinct from the per-[`ServeMetrics`] clock, which
/// resets on generation swaps and registry reloads.
///
/// [`Server::start_with_registry`]: crate::server::http::Server::start_with_registry
static PROCESS_START: OnceLock<Instant> = OnceLock::new();

/// Anchor the process-uptime clock. Idempotent — the first call wins.
pub fn process_start() {
    let _ = PROCESS_START.get_or_init(Instant::now);
}

/// Seconds since [`process_start`] first ran (anchors now if it never did,
/// so a bare scrape still reads a sane 0-ish value instead of garbage).
pub fn process_uptime_secs() -> f64 {
    PROCESS_START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Build identity for the `pgpr_build_info` gauge: crate version and the
/// compiled feature set (what this binary can actually do — `simd` changes
/// the serve hot path, so scrapes should be attributable to it).
pub fn build_info() -> (&'static str, &'static str) {
    let features = if cfg!(feature = "simd") { "simd" } else { "default" };
    (env!("CARGO_PKG_VERSION"), features)
}

/// Values below this get exact unit buckets; above, log-linear octaves.
const LINEAR_MAX: u64 = 8;
/// Sub-buckets per octave (power of two; 8 ⇒ ≤ 1/8 relative error).
const SUB: usize = 8;
/// 8 exact buckets + 8 sub-buckets for each octave 2³..2⁶³.
const NUM_BUCKETS: usize = LINEAR_MAX as usize + (64 - 3) * SUB;

fn bucket_index(v: u64) -> usize {
    if v < LINEAR_MAX {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as usize; // ≥ 3 since v ≥ 8
    let group = msb - 3;
    let sub = ((v >> (msb - 3)) & 0x7) as usize;
    LINEAR_MAX as usize + group * SUB + sub
}

/// Representative (midpoint) value of a bucket.
fn bucket_value(idx: usize) -> u64 {
    if idx < LINEAR_MAX as usize {
        return idx as u64;
    }
    let group = (idx - LINEAR_MAX as usize) / SUB;
    let sub = ((idx - LINEAR_MAX as usize) % SUB) as u64;
    let width = 1u64 << group;
    let lower = (LINEAR_MAX + sub) << group;
    lower + width / 2
}

/// Inclusive upper edge of a bucket — the largest sample value that maps
/// into it. Used as the Prometheus `le` boundary (strictly increasing
/// with the index, so cumulative `_bucket` series are well-formed).
fn bucket_le(idx: usize) -> u64 {
    if idx < LINEAR_MAX as usize {
        return idx as u64;
    }
    let group = (idx - LINEAR_MAX as usize) / SUB;
    let sub = ((idx - LINEAR_MAX as usize) % SUB) as u64;
    let width = 1u64 << group;
    let lower = (LINEAR_MAX + sub) << group;
    // `width - 1` first: the top bucket's edge is exactly `u64::MAX`, so
    // `lower + width` would overflow.
    lower + (width - 1)
}

/// Append one histogram family in Prometheus cumulative exposition:
/// sparse `_bucket{le=…}` lines over the non-empty buckets, a `+Inf`
/// bucket, `_sum` and `_count` — all derived from one bucket scan so the
/// emitted series stay self-consistent under concurrent `record`s.
/// `scale` converts the histogram's integer sample unit into the exposed
/// unit (1e-6 for microsecond samples exposed as seconds, 1.0 for plain
/// counts); `extra` is an optional pre-formatted label pair
/// (`stage="engine"`) appended after the section label.
fn write_histogram(
    s: &mut String,
    name: &str,
    h: &Histogram,
    scale: f64,
    label: Option<(&str, &str)>,
    extra: &str,
) {
    let series_labels = |le: Option<&str>| -> String {
        let mut parts: Vec<String> = Vec::new();
        if let Some((k, v)) = label {
            parts.push(format!("{k}=\"{v}\""));
        }
        if !extra.is_empty() {
            parts.push(extra.to_string());
        }
        if let Some(le) = le {
            parts.push(format!("le=\"{le}\""));
        }
        if parts.is_empty() {
            String::new()
        } else {
            format!("{{{}}}", parts.join(","))
        }
    };
    let buckets = h.cumulative_nonzero();
    let total = buckets.last().map_or(0, |&(_, c)| c);
    for &(le, cum) in &buckets {
        let ls = series_labels(Some(&format!("{}", le as f64 * scale)));
        let _ = writeln!(s, "{name}_bucket{ls} {cum}");
    }
    let ls = series_labels(Some("+Inf"));
    let _ = writeln!(s, "{name}_bucket{ls} {total}");
    let base = series_labels(None);
    let _ = writeln!(s, "{name}_sum{base} {}", h.sum() as f64 * scale);
    let _ = writeln!(s, "{name}_count{base} {total}");
}

/// Concurrent log-linear histogram over `u64` samples.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

/// A point-in-time read of a [`Histogram`].
#[derive(Clone, Copy, Debug, Default)]
pub struct HistSnapshot {
    pub count: u64,
    pub mean: f64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub max: u64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample. Lock-free: three relaxed atomic RMWs.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded samples (same unit as the samples).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// `(le, cumulative_count)` for every non-empty bucket, in increasing
    /// `le` order. The final cumulative count is the self-consistent
    /// total for a `+Inf` bucket (summed from the same bucket reads, so a
    /// concurrent `record` can never make `+Inf` disagree with the
    /// emitted `_count`). Sparse on purpose: the 496 fixed buckets would
    /// bloat every scrape, and Prometheus only needs the edges that hold
    /// observations.
    pub fn cumulative_nonzero(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c > 0 {
                cum += c;
                out.push((bucket_le(i), cum));
            }
        }
        out
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// The `q`-quantile (q in [0, 1]) of everything recorded so far,
    /// accurate to the bucket resolution and capped at the observed max.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let mut target = ((q * n as f64).ceil() as u64).clamp(1, n);
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c >= target {
                return bucket_value(i).min(self.max());
            }
            target -= c;
        }
        self.max()
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            count: self.count(),
            mean: self.mean(),
            p50: self.quantile(0.5),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            max: self.max(),
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// One [`Histogram`] per pipeline [`Stage`] (samples in microseconds).
/// Same concurrency contract as the other histograms: relaxed atomics,
/// no locks, written from connection workers + the batcher thread and
/// read by `/metrics` renders.
pub struct StageStats {
    hists: [Histogram; STAGE_COUNT],
}

impl StageStats {
    pub fn new() -> StageStats {
        StageStats { hists: std::array::from_fn(|_| Histogram::new()) }
    }

    /// Record one stage duration.
    pub fn record(&self, stage: Stage, secs: f64) {
        self.hists[stage as usize].record((secs * 1e6) as u64);
    }

    /// Record every stage a request touched (the non-zero entries of its
    /// [`StageSet`]).
    pub fn record_set(&self, set: &StageSet) {
        for (stage, secs) in set.iter_nonzero() {
            self.record(stage, secs);
        }
    }

    pub fn get(&self, stage: Stage) -> &Histogram {
        &self.hists[stage as usize]
    }
}

impl Default for StageStats {
    fn default() -> Self {
        StageStats::new()
    }
}

/// Shared metrics for the serving path. All members use interior
/// mutability (atomics), so one `Arc<ServeMetrics>` is read and written
/// from connection workers, the batcher thread and `/metrics` renders
/// concurrently.
pub struct ServeMetrics {
    /// Per-row latency, enqueue → batch answered, microseconds.
    pub latency_us: Histogram,
    /// Engine predict call duration per batch, microseconds.
    pub predict_us: Histogram,
    /// Rows per flushed batch (occupancy).
    pub batch_rows: Histogram,
    /// Requests waiting in the bounded submit queue (the one whose
    /// saturation produces 503s), sampled at each successful enqueue
    /// including the new request.
    pub queue_depth: Histogram,
    /// End-to-end latency of published online updates (absorb + generation
    /// swap), microseconds.
    pub observe_us: Histogram,
    /// Observation rows accepted into the model's stream.
    pub observe_rows: AtomicU64,
    /// Rows accepted into the queue.
    pub requests: AtomicU64,
    /// Rows answered.
    pub responses: AtomicU64,
    /// Failed requests, counted once per 4xx/5xx response at the HTTP
    /// boundary (engine failures surface there as 500s).
    pub errors: AtomicU64,
    /// Batches flushed.
    pub batches: AtomicU64,
    /// Requests refused by the admission gate / overload paths, one
    /// counter per [`ShedReason`] (`pgpr_requests_shed_total{reason=…}`).
    pub shed: [AtomicU64; SHED_REASONS],
    /// Times this model's batcher thread was respawned after a panic
    /// (`pgpr_batcher_restarts_total`).
    pub batcher_restarts: AtomicU64,
    /// Per-stage latency attribution (`pgpr_stage_seconds`).
    pub stages: StageStats,
    /// Ring of the last N completed request traces (`GET /debug/trace`).
    /// Lives here — not on the engine — so traces survive generation
    /// swaps, like every other per-model series.
    pub trace: TraceRing,
    started: Instant,
}

/// Trace-ring capacity when none is configured (`ServeOptions::trace_ring`).
pub const DEFAULT_TRACE_RING: usize = 256;

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics::with_trace_capacity(DEFAULT_TRACE_RING)
    }

    /// Metrics whose trace ring holds the last `trace_ring` requests
    /// (0 disables trace recording entirely).
    pub fn with_trace_capacity(trace_ring: usize) -> ServeMetrics {
        ServeMetrics {
            latency_us: Histogram::new(),
            predict_us: Histogram::new(),
            batch_rows: Histogram::new(),
            queue_depth: Histogram::new(),
            observe_us: Histogram::new(),
            observe_rows: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            shed: std::array::from_fn(|_| AtomicU64::new(0)),
            batcher_restarts: AtomicU64::new(0),
            stages: StageStats::new(),
            trace: TraceRing::new(trace_ring),
            started: Instant::now(),
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Count one shed request (refused before reaching the engine).
    pub fn record_shed(&self, reason: ShedReason) {
        self.shed[reason as usize].fetch_add(1, Ordering::Relaxed);
    }

    /// Total sheds across every reason.
    pub fn shed_total(&self) -> u64 {
        self.shed.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    /// Rows answered per wall-clock second since the metrics were created.
    pub fn rows_per_sec(&self) -> f64 {
        let secs = self.elapsed_secs();
        if secs <= 0.0 {
            0.0
        } else {
            self.responses.load(Ordering::Relaxed) as f64 / secs
        }
    }

    /// Prometheus text exposition for `GET /metrics`.
    pub fn render_prometheus(&self) -> String {
        self.render_prometheus_with(None)
    }

    /// Prometheus text with an optional label attached to every series —
    /// `Some(("model", "alpha"))` renders the per-model section of a
    /// multi-model `/metrics` page; `None` renders the unlabeled primary
    /// section. Samples only: the `# HELP`/`# TYPE` header block comes
    /// from [`render_metadata`], emitted exactly once per page by the
    /// HTTP layer (this function runs once unlabeled plus once per
    /// resident model, so inlining metadata here would duplicate it).
    pub fn render_prometheus_with(&self, label: Option<(&str, &str)>) -> String {
        // Build `{k="v"}`, `{reason="r"}` or `{k="v",reason="r"}`.
        let lbl = |extra: &str| -> String {
            match (label, extra.is_empty()) {
                (None, true) => String::new(),
                (None, false) => format!("{{{extra}}}"),
                (Some((k, v)), true) => format!("{{{k}=\"{v}\"}}"),
                (Some((k, v)), false) => format!("{{{k}=\"{v}\",{extra}}}"),
            }
        };
        let plain = lbl("");
        let mut s = String::with_capacity(1024);
        let c = |a: &AtomicU64| a.load(Ordering::Relaxed);
        let _ = writeln!(s, "pgpr_requests_total{plain} {}", c(&self.requests));
        let _ = writeln!(s, "pgpr_responses_total{plain} {}", c(&self.responses));
        let _ = writeln!(s, "pgpr_errors_total{plain} {}", c(&self.errors));
        let _ = writeln!(s, "pgpr_batches_total{plain} {}", c(&self.batches));
        let _ = writeln!(s, "pgpr_throughput_rows_per_sec{plain} {:.3}", self.rows_per_sec());
        let _ = writeln!(s, "pgpr_uptime_seconds{plain} {:.3}", self.elapsed_secs());
        let _ = writeln!(s, "pgpr_observe_rows_total{plain} {}", c(&self.observe_rows));
        for reason in ALL_SHED_REASONS.iter().copied() {
            let rs = lbl(&format!("reason=\"{}\"", reason.label()));
            let _ =
                writeln!(s, "pgpr_requests_shed_total{rs} {}", c(&self.shed[reason as usize]));
        }
        let _ = writeln!(s, "pgpr_batcher_restarts_total{plain} {}", c(&self.batcher_restarts));
        // Latency-class histograms: microsecond samples exposed in
        // seconds as cumulative `_bucket{le}`/`_sum`/`_count`, with the
        // pre-computed mean/max kept as companion gauge families (the
        // quantile snapshots stay available via `?format=json`).
        for (name, h) in [
            ("pgpr_request_latency_seconds", &self.latency_us),
            ("pgpr_predict_seconds", &self.predict_us),
            ("pgpr_observe_update_seconds", &self.observe_us),
        ] {
            write_histogram(&mut s, name, h, 1e-6, label, "");
            let _ = writeln!(s, "{name}_mean{plain} {:.6e}", h.mean() * 1e-6);
            let _ = writeln!(s, "{name}_max{plain} {:.6e}", h.max() as f64 * 1e-6);
        }
        for (name, h) in [
            ("pgpr_batch_occupancy_rows", &self.batch_rows),
            ("pgpr_queue_depth_requests", &self.queue_depth),
        ] {
            write_histogram(&mut s, name, h, 1.0, label, "");
            let _ = writeln!(s, "{name}_mean{plain} {:.3}", h.mean());
            let _ = writeln!(s, "{name}_max{plain} {}", h.max());
        }
        // Per-stage attribution: only stages this model has actually
        // touched, so an f64 model doesn't advertise empty f32u series.
        for stage in ALL_STAGES.iter().copied() {
            let h = self.stages.get(stage);
            if h.count() == 0 {
                continue;
            }
            let extra = format!("stage=\"{}\"", stage.name());
            write_histogram(&mut s, "pgpr_stage_seconds", h, 1e-6, label, &extra);
            let ls = lbl(&extra);
            let _ = writeln!(s, "pgpr_stage_seconds_mean{ls} {:.6e}", h.mean() * 1e-6);
        }
        s
    }

    /// Human-readable shutdown summary.
    pub fn summary(&self) -> String {
        let lat = self.latency_us.snapshot();
        let occ = self.batch_rows.snapshot();
        let c = |a: &AtomicU64| a.load(Ordering::Relaxed);
        format!(
            "served {} rows in {} batches ({} errors); latency mean {:.3}ms p50 {:.3}ms p95 {:.3}ms p99 {:.3}ms max {:.3}ms; \
             mean batch occupancy {:.2} rows; throughput {:.1} rows/s over {:.2}s",
            c(&self.responses),
            c(&self.batches),
            c(&self.errors),
            lat.mean * 1e-3,
            lat.p50 as f64 * 1e-3,
            lat.p95 as f64 * 1e-3,
            lat.p99 as f64 * 1e-3,
            lat.max as f64 * 1e-3,
            occ.mean,
            self.rows_per_sec(),
            self.elapsed_secs(),
        )
    }

    /// Machine-readable snapshot (embedded in `BENCH_serve_latency.json`).
    pub fn to_json(&self) -> Json {
        let lat = self.latency_us.snapshot();
        let occ = self.batch_rows.snapshot();
        let qd = self.queue_depth.snapshot();
        let obs = self.observe_us.snapshot();
        let c = |a: &AtomicU64| Json::Num(a.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("requests", c(&self.requests)),
            ("responses", c(&self.responses)),
            ("errors", c(&self.errors)),
            ("batches", c(&self.batches)),
            ("throughput_rows_per_sec", Json::Num(self.rows_per_sec())),
            (
                "latency_s",
                Json::obj(vec![
                    ("mean", Json::Num(lat.mean * 1e-6)),
                    ("p50", Json::Num(lat.p50 as f64 * 1e-6)),
                    ("p95", Json::Num(lat.p95 as f64 * 1e-6)),
                    ("p99", Json::Num(lat.p99 as f64 * 1e-6)),
                    ("max", Json::Num(lat.max as f64 * 1e-6)),
                ]),
            ),
            (
                "batch_occupancy_rows",
                Json::obj(vec![
                    ("mean", Json::Num(occ.mean)),
                    ("p50", Json::Num(occ.p50 as f64)),
                    ("max", Json::Num(occ.max as f64)),
                ]),
            ),
            (
                "queue_depth_requests",
                Json::obj(vec![
                    ("mean", Json::Num(qd.mean)),
                    ("p99", Json::Num(qd.p99 as f64)),
                    ("max", Json::Num(qd.max as f64)),
                ]),
            ),
            ("observe_rows", c(&self.observe_rows)),
            (
                "shed",
                Json::obj(
                    ALL_SHED_REASONS
                        .iter()
                        .map(|&r| (r.label(), c(&self.shed[r as usize])))
                        .collect(),
                ),
            ),
            ("batcher_restarts", c(&self.batcher_restarts)),
            (
                "observe_update_s",
                Json::obj(vec![
                    ("mean", Json::Num(obs.mean * 1e-6)),
                    ("p50", Json::Num(obs.p50 as f64 * 1e-6)),
                    ("p99", Json::Num(obs.p99 as f64 * 1e-6)),
                    ("max", Json::Num(obs.max as f64 * 1e-6)),
                ]),
            ),
            ("stages_s", self.stages_json()),
        ])
    }

    /// Per-stage quantile snapshot (seconds) of the stages this model has
    /// touched — the `stages_s` member of [`to_json`](Self::to_json) and
    /// the bench record's per-stage breakdown source.
    pub fn stages_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = Vec::new();
        for stage in ALL_STAGES.iter().copied() {
            let h = self.stages.get(stage);
            if h.count() == 0 {
                continue;
            }
            let sn = h.snapshot();
            fields.push((
                stage.name(),
                Json::obj(vec![
                    ("mean", Json::Num(sn.mean * 1e-6)),
                    ("p50", Json::Num(sn.p50 as f64 * 1e-6)),
                    ("p99", Json::Num(sn.p99 as f64 * 1e-6)),
                    ("count", Json::Num(sn.count as f64)),
                ]),
            ));
        }
        Json::obj(fields)
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics::new()
    }
}

/// `(family, type, help)` for every metric family the `/metrics` page can
/// emit — the serve-path families rendered by [`ServeMetrics`] plus the
/// process-wide families `server::http` adds around them (build info,
/// model registry gauges, resource/profiler gauges). One shared table
/// keeps `# HELP`/`# TYPE` exactly-once per exposition: the HTTP layer
/// renders [`render_metadata`] once at the top of the page and every
/// section below emits samples only. Metadata for a family with no
/// samples on a given scrape is legal, so quiet families cost two lines.
const FAMILY_METADATA: &[(&str, &str, &str)] = &[
    ("pgpr_requests_total", "counter", "Prediction rows accepted into the submit queue."),
    ("pgpr_responses_total", "counter", "Prediction rows answered."),
    ("pgpr_errors_total", "counter", "Requests answered 4xx/5xx at the HTTP boundary."),
    ("pgpr_batches_total", "counter", "Micro-batches flushed to the engine."),
    ("pgpr_throughput_rows_per_sec", "gauge", "Rows answered per second since section start."),
    ("pgpr_uptime_seconds", "gauge", "Seconds since this metrics section was created."),
    ("pgpr_observe_rows_total", "counter", "Observation rows accepted into the model stream."),
    ("pgpr_requests_shed_total", "counter", "Requests refused by the admission gate, by reason."),
    ("pgpr_batcher_restarts_total", "counter", "Batcher thread respawns after a panic."),
    ("pgpr_request_latency_seconds", "histogram", "Per-row latency, enqueue to batch answered."),
    ("pgpr_request_latency_seconds_mean", "gauge", "Mean per-row latency in seconds."),
    ("pgpr_request_latency_seconds_max", "gauge", "Largest per-row latency in seconds."),
    ("pgpr_predict_seconds", "histogram", "Engine predict call duration per batch."),
    ("pgpr_predict_seconds_mean", "gauge", "Mean engine predict duration in seconds."),
    ("pgpr_predict_seconds_max", "gauge", "Largest engine predict duration in seconds."),
    ("pgpr_observe_update_seconds", "histogram", "Published online-update latency."),
    ("pgpr_observe_update_seconds_mean", "gauge", "Mean online-update latency in seconds."),
    ("pgpr_observe_update_seconds_max", "gauge", "Largest online-update latency in seconds."),
    ("pgpr_batch_occupancy_rows", "histogram", "Rows per flushed micro-batch."),
    ("pgpr_batch_occupancy_rows_mean", "gauge", "Mean rows per flushed micro-batch."),
    ("pgpr_batch_occupancy_rows_max", "gauge", "Largest flushed micro-batch in rows."),
    ("pgpr_queue_depth_requests", "histogram", "Submit-queue depth sampled at each enqueue."),
    ("pgpr_queue_depth_requests_mean", "gauge", "Mean sampled submit-queue depth."),
    ("pgpr_queue_depth_requests_max", "gauge", "Largest sampled submit-queue depth."),
    ("pgpr_stage_seconds", "histogram", "Per-request latency attributed to pipeline stages."),
    ("pgpr_stage_seconds_mean", "gauge", "Mean per-stage latency in seconds."),
    ("pgpr_process_uptime_seconds", "gauge", "Seconds since process boot."),
    ("pgpr_build_info", "gauge", "Build identity (crate version, compiled features)."),
    ("pgpr_models_resident", "gauge", "Models resident in the serving registry."),
    ("pgpr_model_requests_total", "counter", "Answered requests per resident model."),
    ("pgpr_model_generation", "gauge", "Current published generation per model."),
    ("pgpr_model_train_rows", "gauge", "Training rows absorbed per model."),
    ("pgpr_generation_inflight", "gauge", "Requests in flight against the live generation."),
    ("pgpr_model_quality", "gauge", "Windowed prequential quality metrics per model."),
    ("pgpr_model_drift_score", "gauge", "Drift score vs the fit-time baseline per model."),
    ("pgpr_process_rss_bytes", "gauge", "Resident set size from /proc/self/status."),
    ("pgpr_process_heap_live_bytes", "gauge", "Live bytes held via the tracking allocator."),
    ("pgpr_process_heap_peak_bytes", "gauge", "High-water mark of tracked live heap bytes."),
    ("pgpr_process_open_fds", "gauge", "Open file descriptors of this process."),
    ("pgpr_process_open_connections", "gauge", "HTTP connections currently being served."),
    ("pgpr_process_cpu_seconds_total", "counter", "Process CPU time (user+system)."),
    ("pgpr_cpu_saturation_ratio", "gauge", "Smoothed process CPU utilization in [0, 1]."),
    ("pgpr_thread_cpu_seconds_total", "counter", "CPU time per named thread (user+system)."),
];

/// The `# HELP`/`# TYPE` header block for every family in
/// [`FAMILY_METADATA`]. `server::http` prepends this exactly once per
/// `/metrics` page; sample-rendering code never emits metadata.
pub fn render_metadata() -> String {
    let mut s = String::with_capacity(4096);
    for (name, ty, help) in FAMILY_METADATA {
        let _ = writeln!(s, "# HELP {name} {help}");
        let _ = writeln!(s, "# TYPE {name} {ty}");
    }
    s
}

/// Parse one `{…}` label body into `(key, value)` pairs, honoring the
/// Prometheus escapes (`\\`, `\"`, `\n`) so label values may contain
/// commas and quotes.
fn parse_labels(body: &str) -> std::result::Result<Vec<(String, String)>, String> {
    let mut out = Vec::new();
    let mut rest = body.trim();
    while !rest.is_empty() {
        let eq = rest
            .find('=')
            .ok_or_else(|| format!("label without `=` in `{body}`"))?;
        let key = rest[..eq].trim().to_string();
        if key.is_empty()
            || !key
                .chars()
                .enumerate()
                .all(|(i, c)| c == '_' || c.is_ascii_alphabetic() || (i > 0 && c.is_ascii_digit()))
        {
            return Err(format!("bad label name `{key}` in `{body}`"));
        }
        rest = rest[eq + 1..].trim_start();
        if !rest.starts_with('"') {
            return Err(format!("unquoted label value in `{body}`"));
        }
        let mut val = String::new();
        let mut end = None;
        let mut esc = false;
        for (i, ch) in rest.char_indices().skip(1) {
            if esc {
                val.push(ch);
                esc = false;
            } else if ch == '\\' {
                esc = true;
            } else if ch == '"' {
                end = Some(i);
                break;
            } else {
                val.push(ch);
            }
        }
        let end = end.ok_or_else(|| format!("unterminated label value in `{body}`"))?;
        out.push((key, val));
        rest = rest[end + 1..].trim_start();
        rest = rest.strip_prefix(',').unwrap_or(rest).trim_start();
    }
    Ok(out)
}

/// Self-check a Prometheus text exposition (the whole `/metrics` page):
///
/// * every line is a well-formed comment or `name[{labels}] value` sample;
/// * every sample's family carries a `# TYPE` declaration, declared at
///   most once (`# HELP` likewise);
/// * no series (name + label set) is emitted twice;
/// * a `histogram` family emits only `_bucket`/`_sum`/`_count` samples,
///   every `_bucket` carries `le`, bucket edges strictly increase with
///   non-decreasing cumulative counts, and the series ends with a `+Inf`
///   bucket equal to its `_count` twin.
///
/// Used by the exposition tests (and callers who want a cheap runtime
/// assert) so a format regression fails loudly instead of silently
/// breaking scrapers.
pub fn lint_exposition(text: &str) -> std::result::Result<(), String> {
    use std::collections::{HashMap, HashSet};
    fn valid_name(n: &str) -> bool {
        !n.is_empty()
            && n.chars().enumerate().all(|(i, c)| {
                c == '_' || c == ':' || c.is_ascii_alphabetic() || (i > 0 && c.is_ascii_digit())
            })
    }
    let mut types: HashMap<String, String> = HashMap::new();
    let mut helps: HashSet<String> = HashSet::new();
    // (family+labels-sans-le) → [(le, cumulative)] in emission order.
    let mut buckets: HashMap<String, Vec<(f64, f64)>> = HashMap::new();
    let mut counts: HashMap<String, f64> = HashMap::new();
    let mut sums: HashSet<String> = HashSet::new();
    let mut seen_series: HashSet<String> = HashSet::new();
    // Single pass; a family's metadata must precede its samples, which
    // is how this crate renders pages (metadata block first).
    for (ln, line) in text.lines().enumerate() {
        let ln = ln + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.splitn(2, ' ');
            let name = it.next().unwrap_or("");
            let ty = it.next().unwrap_or("").trim();
            if !valid_name(name) {
                return Err(format!("line {ln}: bad family name in TYPE `{line}`"));
            }
            if !matches!(ty, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                return Err(format!("line {ln}: unknown metric type `{ty}`"));
            }
            if types.insert(name.to_string(), ty.to_string()).is_some() {
                return Err(format!("line {ln}: duplicate TYPE for `{name}`"));
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split(' ').next().unwrap_or("");
            if !valid_name(name) {
                return Err(format!("line {ln}: bad family name in HELP `{line}`"));
            }
            if !helps.insert(name.to_string()) {
                return Err(format!("line {ln}: duplicate HELP for `{name}`"));
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // free-form comment
        }
        // Sample: `name value` or `name{labels} value`.
        let (name, labels, value) = if let Some(open) = line.find('{') {
            let close = line
                .rfind('}')
                .ok_or_else(|| format!("line {ln}: unclosed label set `{line}`"))?;
            let labels = parse_labels(&line[open + 1..close])
                .map_err(|e| format!("line {ln}: {e}"))?;
            (&line[..open], labels, line[close + 1..].trim())
        } else {
            let sp = line
                .find(' ')
                .ok_or_else(|| format!("line {ln}: sample without value `{line}`"))?;
            (&line[..sp], Vec::new(), line[sp + 1..].trim())
        };
        if !valid_name(name) {
            return Err(format!("line {ln}: bad metric name `{name}`"));
        }
        let val: f64 = match value {
            "+Inf" => f64::INFINITY,
            "-Inf" => f64::NEG_INFINITY,
            "NaN" => f64::NAN,
            v => v
                .parse()
                .map_err(|_| format!("line {ln}: bad sample value `{v}`"))?,
        };
        // Resolve the owning family: histogram samples use suffixed names.
        let family = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|suf| {
                let base = name.strip_suffix(suf)?;
                (types.get(base).map(String::as_str) == Some("histogram")).then_some(base)
            })
            .unwrap_or(name);
        let Some(ty) = types.get(family) else {
            return Err(format!("line {ln}: sample `{name}` has no `# TYPE {family}` metadata"));
        };
        let mut sorted: Vec<&(String, String)> = labels.iter().collect();
        sorted.sort();
        let series = format!(
            "{name}|{}",
            sorted.iter().map(|(k, v)| format!("{k}={v}")).collect::<Vec<_>>().join(",")
        );
        if !seen_series.insert(series) {
            return Err(format!("line {ln}: duplicate series `{line}`"));
        }
        if ty == "histogram" {
            if family == name {
                return Err(format!(
                    "line {ln}: histogram `{family}` may only emit _bucket/_sum/_count"
                ));
            }
            let key = format!(
                "{family}|{}",
                sorted
                    .iter()
                    .filter(|(k, _)| k != "le")
                    .map(|(k, v)| format!("{k}={v}"))
                    .collect::<Vec<_>>()
                    .join(",")
            );
            if name.ends_with("_bucket") {
                let le = labels
                    .iter()
                    .find(|(k, _)| k == "le")
                    .map(|(_, v)| v.as_str())
                    .ok_or_else(|| format!("line {ln}: `{name}` bucket without `le`"))?;
                let le: f64 = match le {
                    "+Inf" => f64::INFINITY,
                    v => v
                        .parse()
                        .map_err(|_| format!("line {ln}: bad le `{v}`"))?,
                };
                let series = buckets.entry(key).or_default();
                if let Some(&(prev_le, prev_cum)) = series.last() {
                    if le <= prev_le {
                        return Err(format!("line {ln}: bucket edges not increasing at le={le}"));
                    }
                    if val < prev_cum {
                        return Err(format!("line {ln}: cumulative bucket count decreased"));
                    }
                }
                series.push((le, val));
            } else if name.ends_with("_sum") {
                sums.insert(key);
            } else {
                counts.insert(key, val);
            }
        }
    }
    for (key, series) in &buckets {
        let Some(&(last_le, last_cum)) = series.last() else { continue };
        if last_le != f64::INFINITY {
            return Err(format!("histogram `{key}` has no +Inf bucket"));
        }
        if !sums.contains(key) {
            return Err(format!("histogram `{key}` has buckets but no _sum"));
        }
        match counts.get(key) {
            None => return Err(format!("histogram `{key}` has buckets but no _count")),
            Some(&c) if c != last_cum => {
                return Err(format!(
                    "histogram `{key}`: +Inf bucket {last_cum} != _count {c}"
                ))
            }
            _ => {}
        }
    }
    for key in counts.keys() {
        if !buckets.contains_key(key) {
            return Err(format!("histogram `{key}` has _count but no buckets"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_in_range() {
        let mut prev = 0usize;
        for v in [0u64, 1, 7, 8, 9, 15, 16, 31, 100, 1000, 1 << 20, u64::MAX / 2, u64::MAX] {
            let idx = bucket_index(v);
            assert!(idx < NUM_BUCKETS, "v={v} idx={idx}");
            assert!(idx >= prev, "index not monotone at v={v}");
            prev = idx;
        }
    }

    #[test]
    fn bucket_value_within_relative_error() {
        for v in [12u64, 100, 999, 4096, 123_456, 9_999_999] {
            let rep = bucket_value(bucket_index(v));
            let err = (rep as f64 - v as f64).abs() / v as f64;
            assert!(err <= 0.13, "v={v} rep={rep} err={err}");
        }
    }

    #[test]
    fn small_values_are_exact() {
        let h = Histogram::new();
        for v in 0..8u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.max(), 7);
        assert_eq!(h.quantile(0.01), 0);
        assert_eq!(h.quantile(1.0), 7);
    }

    #[test]
    fn quantiles_order_correctly() {
        let h = Histogram::new();
        // 90 fast samples around 100, 10 slow around 10_000.
        for _ in 0..90 {
            h.record(100);
        }
        for _ in 0..10 {
            h.record(10_000);
        }
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 >= 80 && p50 <= 120, "p50={p50}");
        assert!(p95 >= 8_000, "p95={p95}");
        assert!(p99 >= p95 && p99 <= h.max());
        assert!((h.mean() - (90.0 * 100.0 + 10.0 * 10_000.0) / 100.0).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99, 0);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let h = Histogram::new();
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = &h;
                s.spawn(move || {
                    for i in 0..1000u64 {
                        h.record(t * 1000 + i);
                    }
                });
            }
        });
        assert_eq!(h.count(), 4000);
        assert_eq!(h.max(), 3999);
    }

    #[test]
    fn labeled_render_tags_every_series() {
        let m = ServeMetrics::new();
        m.requests.fetch_add(2, Ordering::Relaxed);
        m.latency_us.record(900);
        let text = m.render_prometheus_with(Some(("model", "alpha")));
        assert!(text.contains("pgpr_requests_total{model=\"alpha\"} 2"));
        assert!(
            text.contains("pgpr_request_latency_seconds_bucket{model=\"alpha\",le=\"+Inf\"} 1"),
            "{text}"
        );
        assert!(text.contains("pgpr_request_latency_seconds_sum{model=\"alpha\"} 0.0009"));
        assert!(text.contains("pgpr_request_latency_seconds_count{model=\"alpha\"} 1"));
        // Exactly one finite bucket for a single sample, below +Inf.
        assert_eq!(text.matches("pgpr_request_latency_seconds_bucket{").count(), 2);
        // Unlabeled renders the same shape without the model label.
        let plain = m.render_prometheus();
        assert!(plain.contains("pgpr_requests_total 2"));
        assert!(plain.contains("pgpr_request_latency_seconds_bucket{le=\"+Inf\"} 1"));
        // No quantile-labeled text series — those live in `?format=json`.
        assert!(!plain.contains("quantile=\""));
    }

    #[test]
    fn bucket_le_is_inclusive_upper_edge() {
        // `le` is the largest value mapping into its bucket, and the edge
        // sequence strictly increases — cumulative exposition needs both.
        let mut prev = None;
        for idx in 0..NUM_BUCKETS {
            let le = bucket_le(idx);
            assert_eq!(bucket_index(le), idx, "le {le} maps back into bucket {idx}");
            if le < u64::MAX {
                assert!(bucket_index(le + 1) > idx, "le {le} is not the upper edge of {idx}");
            }
            if let Some(p) = prev {
                assert!(le > p, "edges not strictly increasing at idx {idx}");
            }
            prev = Some(le);
        }
    }

    #[test]
    fn cumulative_nonzero_is_sparse_and_consistent() {
        let h = Histogram::new();
        for _ in 0..90 {
            h.record(100);
        }
        for _ in 0..10 {
            h.record(10_000);
        }
        let buckets = h.cumulative_nonzero();
        assert_eq!(buckets.len(), 2, "two distinct buckets touched");
        assert_eq!(buckets[0].1, 90);
        assert_eq!(buckets[1].1, 100);
        assert!(buckets[0].0 < buckets[1].0);
        assert!(buckets[0].0 >= 100 && buckets[1].0 >= 10_000, "le is an upper edge");
        assert_eq!(h.sum(), 90 * 100 + 10 * 10_000);
    }

    #[test]
    fn exposition_passes_its_own_lint() {
        let m = ServeMetrics::new();
        m.requests.fetch_add(5, Ordering::Relaxed);
        m.latency_us.record(1500);
        m.latency_us.record(80);
        m.batch_rows.record(3);
        m.record_shed(ShedReason::Cpu);
        m.stages.record(Stage::QueueWait, 0.0015);
        let page = format!(
            "{}{}{}",
            render_metadata(),
            m.render_prometheus(),
            m.render_prometheus_with(Some(("model", "alpha")))
        );
        lint_exposition(&page).expect("own exposition lints clean");
    }

    #[test]
    fn lint_rejects_malformed_expositions() {
        // Sample without TYPE metadata.
        assert!(lint_exposition("pgpr_mystery_total 1\n").is_err());
        // Duplicate series.
        let dup = "# TYPE x_total counter\nx_total 1\nx_total 2\n";
        assert!(lint_exposition(dup).is_err());
        // Histogram whose +Inf bucket disagrees with _count.
        let bad = "# TYPE h histogram\n\
                   h_bucket{le=\"1\"} 1\nh_bucket{le=\"+Inf\"} 1\nh_sum 1\nh_count 2\n";
        assert!(lint_exposition(bad).is_err());
        // Bucket edges must increase.
        let edges = "# TYPE h histogram\n\
                     h_bucket{le=\"2\"} 1\nh_bucket{le=\"1\"} 2\n";
        assert!(lint_exposition(edges).is_err());
        // Missing +Inf bucket.
        let noinf = "# TYPE h histogram\nh_bucket{le=\"1\"} 1\nh_sum 1\nh_count 1\n";
        assert!(lint_exposition(noinf).is_err());
        // A clean minimal histogram passes.
        let ok = "# TYPE h histogram\n\
                  h_bucket{le=\"1\"} 1\nh_bucket{le=\"+Inf\"} 2\nh_sum 3\nh_count 2\n";
        lint_exposition(ok).expect("minimal histogram lints clean");
    }

    #[test]
    fn parse_labels_handles_escapes() {
        let got = parse_labels(r#"thread="a\\b\"c",le="+Inf""#).unwrap();
        assert_eq!(got[0].0, "thread");
        assert_eq!(got[0].1, "a\\b\"c");
        assert_eq!(got[1], ("le".to_string(), "+Inf".to_string()));
        assert!(parse_labels("noequals").is_err());
        assert!(parse_labels("k=unquoted").is_err());
    }

    #[test]
    fn stage_series_render_only_when_touched() {
        let m = ServeMetrics::new();
        m.stages.record(Stage::QueueWait, 0.0015);
        let mut set = StageSet::new();
        set.add(Stage::Serialize, 0.0002);
        m.stages.record_set(&set);
        let text = m.render_prometheus_with(Some(("model", "a")));
        assert!(
            text.contains("pgpr_stage_seconds_bucket{model=\"a\",stage=\"queue_wait\",le=\"+Inf\"} 1"),
            "{text}"
        );
        assert!(text.contains("pgpr_stage_seconds_count{model=\"a\",stage=\"serialize\"} 1"));
        assert!(!text.contains("stage=\"f32u\""), "untouched stages must not render");
        let j = m.to_json();
        let stages = j.req("stages_s").unwrap();
        assert_eq!(
            stages.get("queue_wait").unwrap().get("count").unwrap().as_usize(),
            Some(1)
        );
        assert!(stages.get("f32u").is_none());
    }

    #[test]
    fn shed_and_restart_counters_render_and_json() {
        let m = ServeMetrics::new();
        m.record_shed(ShedReason::Slo);
        m.record_shed(ShedReason::Slo);
        m.record_shed(ShedReason::QueueFull);
        m.batcher_restarts.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.shed_total(), 3);
        let text = m.render_prometheus_with(Some(("model", "a")));
        assert!(text.contains("pgpr_requests_shed_total{model=\"a\",reason=\"slo\"} 2"), "{text}");
        assert!(text.contains("pgpr_requests_shed_total{model=\"a\",reason=\"queue_full\"} 1"));
        assert!(
            text.contains("pgpr_requests_shed_total{model=\"a\",reason=\"deadline\"} 0"),
            "zero-valued reasons still render"
        );
        assert!(text.contains("pgpr_batcher_restarts_total{model=\"a\"} 1"));
        let j = m.to_json();
        let shed = j.req("shed").unwrap();
        assert_eq!(shed.get("slo").unwrap().as_usize(), Some(2));
        assert_eq!(shed.get("shutdown").unwrap().as_usize(), Some(0));
        assert_eq!(j.req("batcher_restarts").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn process_uptime_monotone_and_build_info_sane() {
        process_start();
        let a = process_uptime_secs();
        let b = process_uptime_secs();
        assert!(a >= 0.0 && b >= a, "uptime went backwards: {a} -> {b}");
        let (version, features) = build_info();
        assert_eq!(version, env!("CARGO_PKG_VERSION"));
        assert!(features == "simd" || features == "default");
    }

    #[test]
    fn serve_metrics_render_and_json() {
        let m = ServeMetrics::new();
        m.requests.fetch_add(5, Ordering::Relaxed);
        m.responses.fetch_add(5, Ordering::Relaxed);
        m.batches.fetch_add(2, Ordering::Relaxed);
        m.latency_us.record(1500);
        m.batch_rows.record(3);
        m.batch_rows.record(2);
        let text = m.render_prometheus();
        assert!(text.contains("pgpr_requests_total 5"));
        assert!(text.contains("pgpr_request_latency_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("pgpr_batch_occupancy_rows_count 2"));
        assert!(text.contains("pgpr_batch_occupancy_rows_sum 5"));
        let j = m.to_json();
        assert_eq!(j.req("responses").unwrap().as_usize(), Some(5));
        assert!(j.req("latency_s").unwrap().get("p99").unwrap().as_f64().unwrap() > 0.0);
        let s = m.summary();
        assert!(s.contains("served 5 rows in 2 batches"));
    }
}
