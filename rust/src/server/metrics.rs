//! Lock-cheap serving metrics: atomic log-linear histograms and counters.
//!
//! [`Histogram`] records `u64` samples (latencies in microseconds, batch
//! occupancies, queue depths) into fixed log-linear buckets — 8 sub-buckets
//! per octave, ≤ 12.5% relative error — using only relaxed atomic
//! increments, so many connection workers can record concurrently with no
//! lock and no allocation. Quantiles are computed on read by a bucket
//! scan. [`ServeMetrics`] groups the histograms and counters the serving
//! path shares, renders them in Prometheus text format for `GET /metrics`
//! and as a human summary for shutdown.

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::obs::{Stage, StageSet, TraceRing, ALL_STAGES, STAGE_COUNT};
use crate::server::admission::{ShedReason, ALL_SHED_REASONS, SHED_REASONS};
use crate::util::json::Json;

/// Process-wide boot instant behind `pgpr_process_uptime_seconds`.
/// Anchored by the first [`process_start`] call ([`Server::start_with_registry`]
/// calls it at boot); distinct from the per-[`ServeMetrics`] clock, which
/// resets on generation swaps and registry reloads.
///
/// [`Server::start_with_registry`]: crate::server::http::Server::start_with_registry
static PROCESS_START: OnceLock<Instant> = OnceLock::new();

/// Anchor the process-uptime clock. Idempotent — the first call wins.
pub fn process_start() {
    let _ = PROCESS_START.get_or_init(Instant::now);
}

/// Seconds since [`process_start`] first ran (anchors now if it never did,
/// so a bare scrape still reads a sane 0-ish value instead of garbage).
pub fn process_uptime_secs() -> f64 {
    PROCESS_START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Build identity for the `pgpr_build_info` gauge: crate version and the
/// compiled feature set (what this binary can actually do — `simd` changes
/// the serve hot path, so scrapes should be attributable to it).
pub fn build_info() -> (&'static str, &'static str) {
    let features = if cfg!(feature = "simd") { "simd" } else { "default" };
    (env!("CARGO_PKG_VERSION"), features)
}

/// Values below this get exact unit buckets; above, log-linear octaves.
const LINEAR_MAX: u64 = 8;
/// Sub-buckets per octave (power of two; 8 ⇒ ≤ 1/8 relative error).
const SUB: usize = 8;
/// 8 exact buckets + 8 sub-buckets for each octave 2³..2⁶³.
const NUM_BUCKETS: usize = LINEAR_MAX as usize + (64 - 3) * SUB;

fn bucket_index(v: u64) -> usize {
    if v < LINEAR_MAX {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as usize; // ≥ 3 since v ≥ 8
    let group = msb - 3;
    let sub = ((v >> (msb - 3)) & 0x7) as usize;
    LINEAR_MAX as usize + group * SUB + sub
}

/// Representative (midpoint) value of a bucket.
fn bucket_value(idx: usize) -> u64 {
    if idx < LINEAR_MAX as usize {
        return idx as u64;
    }
    let group = (idx - LINEAR_MAX as usize) / SUB;
    let sub = ((idx - LINEAR_MAX as usize) % SUB) as u64;
    let width = 1u64 << group;
    let lower = (LINEAR_MAX + sub) << group;
    lower + width / 2
}

/// Concurrent log-linear histogram over `u64` samples.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

/// A point-in-time read of a [`Histogram`].
#[derive(Clone, Copy, Debug, Default)]
pub struct HistSnapshot {
    pub count: u64,
    pub mean: f64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub max: u64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample. Lock-free: three relaxed atomic RMWs.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// The `q`-quantile (q in [0, 1]) of everything recorded so far,
    /// accurate to the bucket resolution and capped at the observed max.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let mut target = ((q * n as f64).ceil() as u64).clamp(1, n);
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c >= target {
                return bucket_value(i).min(self.max());
            }
            target -= c;
        }
        self.max()
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            count: self.count(),
            mean: self.mean(),
            p50: self.quantile(0.5),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            max: self.max(),
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// One [`Histogram`] per pipeline [`Stage`] (samples in microseconds).
/// Same concurrency contract as the other histograms: relaxed atomics,
/// no locks, written from connection workers + the batcher thread and
/// read by `/metrics` renders.
pub struct StageStats {
    hists: [Histogram; STAGE_COUNT],
}

impl StageStats {
    pub fn new() -> StageStats {
        StageStats { hists: std::array::from_fn(|_| Histogram::new()) }
    }

    /// Record one stage duration.
    pub fn record(&self, stage: Stage, secs: f64) {
        self.hists[stage as usize].record((secs * 1e6) as u64);
    }

    /// Record every stage a request touched (the non-zero entries of its
    /// [`StageSet`]).
    pub fn record_set(&self, set: &StageSet) {
        for (stage, secs) in set.iter_nonzero() {
            self.record(stage, secs);
        }
    }

    pub fn get(&self, stage: Stage) -> &Histogram {
        &self.hists[stage as usize]
    }
}

impl Default for StageStats {
    fn default() -> Self {
        StageStats::new()
    }
}

/// Shared metrics for the serving path. All members use interior
/// mutability (atomics), so one `Arc<ServeMetrics>` is read and written
/// from connection workers, the batcher thread and `/metrics` renders
/// concurrently.
pub struct ServeMetrics {
    /// Per-row latency, enqueue → batch answered, microseconds.
    pub latency_us: Histogram,
    /// Engine predict call duration per batch, microseconds.
    pub predict_us: Histogram,
    /// Rows per flushed batch (occupancy).
    pub batch_rows: Histogram,
    /// Requests waiting in the bounded submit queue (the one whose
    /// saturation produces 503s), sampled at each successful enqueue
    /// including the new request.
    pub queue_depth: Histogram,
    /// End-to-end latency of published online updates (absorb + generation
    /// swap), microseconds.
    pub observe_us: Histogram,
    /// Observation rows accepted into the model's stream.
    pub observe_rows: AtomicU64,
    /// Rows accepted into the queue.
    pub requests: AtomicU64,
    /// Rows answered.
    pub responses: AtomicU64,
    /// Failed requests, counted once per 4xx/5xx response at the HTTP
    /// boundary (engine failures surface there as 500s).
    pub errors: AtomicU64,
    /// Batches flushed.
    pub batches: AtomicU64,
    /// Requests refused by the admission gate / overload paths, one
    /// counter per [`ShedReason`] (`pgpr_requests_shed_total{reason=…}`).
    pub shed: [AtomicU64; SHED_REASONS],
    /// Times this model's batcher thread was respawned after a panic
    /// (`pgpr_batcher_restarts_total`).
    pub batcher_restarts: AtomicU64,
    /// Per-stage latency attribution (`pgpr_stage_seconds`).
    pub stages: StageStats,
    /// Ring of the last N completed request traces (`GET /debug/trace`).
    /// Lives here — not on the engine — so traces survive generation
    /// swaps, like every other per-model series.
    pub trace: TraceRing,
    started: Instant,
}

/// Trace-ring capacity when none is configured (`ServeOptions::trace_ring`).
pub const DEFAULT_TRACE_RING: usize = 256;

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics::with_trace_capacity(DEFAULT_TRACE_RING)
    }

    /// Metrics whose trace ring holds the last `trace_ring` requests
    /// (0 disables trace recording entirely).
    pub fn with_trace_capacity(trace_ring: usize) -> ServeMetrics {
        ServeMetrics {
            latency_us: Histogram::new(),
            predict_us: Histogram::new(),
            batch_rows: Histogram::new(),
            queue_depth: Histogram::new(),
            observe_us: Histogram::new(),
            observe_rows: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            shed: std::array::from_fn(|_| AtomicU64::new(0)),
            batcher_restarts: AtomicU64::new(0),
            stages: StageStats::new(),
            trace: TraceRing::new(trace_ring),
            started: Instant::now(),
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Count one shed request (refused before reaching the engine).
    pub fn record_shed(&self, reason: ShedReason) {
        self.shed[reason as usize].fetch_add(1, Ordering::Relaxed);
    }

    /// Total sheds across every reason.
    pub fn shed_total(&self) -> u64 {
        self.shed.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    /// Rows answered per wall-clock second since the metrics were created.
    pub fn rows_per_sec(&self) -> f64 {
        let secs = self.elapsed_secs();
        if secs <= 0.0 {
            0.0
        } else {
            self.responses.load(Ordering::Relaxed) as f64 / secs
        }
    }

    /// Prometheus text exposition for `GET /metrics`.
    pub fn render_prometheus(&self) -> String {
        self.render_prometheus_with(None)
    }

    /// Prometheus text with an optional label attached to every series —
    /// `Some(("model", "alpha"))` renders the per-model section of a
    /// multi-model `/metrics` page; `None` keeps the legacy unlabeled
    /// format byte-for-byte.
    pub fn render_prometheus_with(&self, label: Option<(&str, &str)>) -> String {
        // Build `{k="v"}`, `{quantile="q"}` or `{k="v",quantile="q"}`.
        let lbl = |extra: &str| -> String {
            match (label, extra.is_empty()) {
                (None, true) => String::new(),
                (None, false) => format!("{{{extra}}}"),
                (Some((k, v)), true) => format!("{{{k}=\"{v}\"}}"),
                (Some((k, v)), false) => format!("{{{k}=\"{v}\",{extra}}}"),
            }
        };
        let plain = lbl("");
        let mut s = String::with_capacity(1024);
        let c = |a: &AtomicU64| a.load(Ordering::Relaxed);
        let _ = writeln!(s, "pgpr_requests_total{plain} {}", c(&self.requests));
        let _ = writeln!(s, "pgpr_responses_total{plain} {}", c(&self.responses));
        let _ = writeln!(s, "pgpr_errors_total{plain} {}", c(&self.errors));
        let _ = writeln!(s, "pgpr_batches_total{plain} {}", c(&self.batches));
        let _ = writeln!(s, "pgpr_throughput_rows_per_sec{plain} {:.3}", self.rows_per_sec());
        let _ = writeln!(s, "pgpr_uptime_seconds{plain} {:.3}", self.elapsed_secs());
        let _ = writeln!(s, "pgpr_observe_rows_total{plain} {}", c(&self.observe_rows));
        for reason in ALL_SHED_REASONS.iter().copied() {
            let rs = lbl(&format!("reason=\"{}\"", reason.label()));
            let _ =
                writeln!(s, "pgpr_requests_shed_total{rs} {}", c(&self.shed[reason as usize]));
        }
        let _ = writeln!(s, "pgpr_batcher_restarts_total{plain} {}", c(&self.batcher_restarts));
        for (name, h) in [
            ("pgpr_request_latency_seconds", &self.latency_us),
            ("pgpr_predict_seconds", &self.predict_us),
            ("pgpr_observe_update_seconds", &self.observe_us),
        ] {
            let snap = h.snapshot();
            for (q, v) in [("0.5", snap.p50), ("0.95", snap.p95), ("0.99", snap.p99)] {
                let qs = lbl(&format!("quantile=\"{q}\""));
                let _ = writeln!(s, "{name}{qs} {:.6e}", v as f64 * 1e-6);
            }
            let _ = writeln!(s, "{name}_mean{plain} {:.6e}", snap.mean * 1e-6);
            let _ = writeln!(s, "{name}_max{plain} {:.6e}", snap.max as f64 * 1e-6);
            let _ = writeln!(s, "{name}_count{plain} {}", snap.count);
        }
        for (name, h) in [
            ("pgpr_batch_occupancy_rows", &self.batch_rows),
            ("pgpr_queue_depth_requests", &self.queue_depth),
        ] {
            let snap = h.snapshot();
            for (q, v) in [("0.5", snap.p50), ("0.95", snap.p95), ("0.99", snap.p99)] {
                let qs = lbl(&format!("quantile=\"{q}\""));
                let _ = writeln!(s, "{name}{qs} {v}");
            }
            let _ = writeln!(s, "{name}_mean{plain} {:.3}", snap.mean);
            let _ = writeln!(s, "{name}_max{plain} {}", snap.max);
        }
        // Per-stage attribution: only stages this model has actually
        // touched, so an f64 model doesn't advertise empty f32u series.
        for stage in ALL_STAGES.iter().copied() {
            let h = self.stages.get(stage);
            if h.count() == 0 {
                continue;
            }
            let snap = h.snapshot();
            for (q, v) in [("0.5", snap.p50), ("0.95", snap.p95), ("0.99", snap.p99)] {
                let qs = lbl(&format!("stage=\"{}\",quantile=\"{q}\"", stage.name()));
                let _ = writeln!(s, "pgpr_stage_seconds{qs} {:.6e}", v as f64 * 1e-6);
            }
            let ls = lbl(&format!("stage=\"{}\"", stage.name()));
            let _ = writeln!(s, "pgpr_stage_seconds_mean{ls} {:.6e}", snap.mean * 1e-6);
            let _ = writeln!(s, "pgpr_stage_seconds_count{ls} {}", snap.count);
        }
        s
    }

    /// Human-readable shutdown summary.
    pub fn summary(&self) -> String {
        let lat = self.latency_us.snapshot();
        let occ = self.batch_rows.snapshot();
        let c = |a: &AtomicU64| a.load(Ordering::Relaxed);
        format!(
            "served {} rows in {} batches ({} errors); latency mean {:.3}ms p50 {:.3}ms p95 {:.3}ms p99 {:.3}ms max {:.3}ms; \
             mean batch occupancy {:.2} rows; throughput {:.1} rows/s over {:.2}s",
            c(&self.responses),
            c(&self.batches),
            c(&self.errors),
            lat.mean * 1e-3,
            lat.p50 as f64 * 1e-3,
            lat.p95 as f64 * 1e-3,
            lat.p99 as f64 * 1e-3,
            lat.max as f64 * 1e-3,
            occ.mean,
            self.rows_per_sec(),
            self.elapsed_secs(),
        )
    }

    /// Machine-readable snapshot (embedded in `BENCH_serve_latency.json`).
    pub fn to_json(&self) -> Json {
        let lat = self.latency_us.snapshot();
        let occ = self.batch_rows.snapshot();
        let qd = self.queue_depth.snapshot();
        let obs = self.observe_us.snapshot();
        let c = |a: &AtomicU64| Json::Num(a.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("requests", c(&self.requests)),
            ("responses", c(&self.responses)),
            ("errors", c(&self.errors)),
            ("batches", c(&self.batches)),
            ("throughput_rows_per_sec", Json::Num(self.rows_per_sec())),
            (
                "latency_s",
                Json::obj(vec![
                    ("mean", Json::Num(lat.mean * 1e-6)),
                    ("p50", Json::Num(lat.p50 as f64 * 1e-6)),
                    ("p95", Json::Num(lat.p95 as f64 * 1e-6)),
                    ("p99", Json::Num(lat.p99 as f64 * 1e-6)),
                    ("max", Json::Num(lat.max as f64 * 1e-6)),
                ]),
            ),
            (
                "batch_occupancy_rows",
                Json::obj(vec![
                    ("mean", Json::Num(occ.mean)),
                    ("p50", Json::Num(occ.p50 as f64)),
                    ("max", Json::Num(occ.max as f64)),
                ]),
            ),
            (
                "queue_depth_requests",
                Json::obj(vec![
                    ("mean", Json::Num(qd.mean)),
                    ("p99", Json::Num(qd.p99 as f64)),
                    ("max", Json::Num(qd.max as f64)),
                ]),
            ),
            ("observe_rows", c(&self.observe_rows)),
            (
                "shed",
                Json::obj(
                    ALL_SHED_REASONS
                        .iter()
                        .map(|&r| (r.label(), c(&self.shed[r as usize])))
                        .collect(),
                ),
            ),
            ("batcher_restarts", c(&self.batcher_restarts)),
            (
                "observe_update_s",
                Json::obj(vec![
                    ("mean", Json::Num(obs.mean * 1e-6)),
                    ("p50", Json::Num(obs.p50 as f64 * 1e-6)),
                    ("p99", Json::Num(obs.p99 as f64 * 1e-6)),
                    ("max", Json::Num(obs.max as f64 * 1e-6)),
                ]),
            ),
            ("stages_s", self.stages_json()),
        ])
    }

    /// Per-stage quantile snapshot (seconds) of the stages this model has
    /// touched — the `stages_s` member of [`to_json`](Self::to_json) and
    /// the bench record's per-stage breakdown source.
    pub fn stages_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = Vec::new();
        for stage in ALL_STAGES.iter().copied() {
            let h = self.stages.get(stage);
            if h.count() == 0 {
                continue;
            }
            let sn = h.snapshot();
            fields.push((
                stage.name(),
                Json::obj(vec![
                    ("mean", Json::Num(sn.mean * 1e-6)),
                    ("p50", Json::Num(sn.p50 as f64 * 1e-6)),
                    ("p99", Json::Num(sn.p99 as f64 * 1e-6)),
                    ("count", Json::Num(sn.count as f64)),
                ]),
            ));
        }
        Json::obj(fields)
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_in_range() {
        let mut prev = 0usize;
        for v in [0u64, 1, 7, 8, 9, 15, 16, 31, 100, 1000, 1 << 20, u64::MAX / 2, u64::MAX] {
            let idx = bucket_index(v);
            assert!(idx < NUM_BUCKETS, "v={v} idx={idx}");
            assert!(idx >= prev, "index not monotone at v={v}");
            prev = idx;
        }
    }

    #[test]
    fn bucket_value_within_relative_error() {
        for v in [12u64, 100, 999, 4096, 123_456, 9_999_999] {
            let rep = bucket_value(bucket_index(v));
            let err = (rep as f64 - v as f64).abs() / v as f64;
            assert!(err <= 0.13, "v={v} rep={rep} err={err}");
        }
    }

    #[test]
    fn small_values_are_exact() {
        let h = Histogram::new();
        for v in 0..8u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.max(), 7);
        assert_eq!(h.quantile(0.01), 0);
        assert_eq!(h.quantile(1.0), 7);
    }

    #[test]
    fn quantiles_order_correctly() {
        let h = Histogram::new();
        // 90 fast samples around 100, 10 slow around 10_000.
        for _ in 0..90 {
            h.record(100);
        }
        for _ in 0..10 {
            h.record(10_000);
        }
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 >= 80 && p50 <= 120, "p50={p50}");
        assert!(p95 >= 8_000, "p95={p95}");
        assert!(p99 >= p95 && p99 <= h.max());
        assert!((h.mean() - (90.0 * 100.0 + 10.0 * 10_000.0) / 100.0).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99, 0);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let h = Histogram::new();
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = &h;
                s.spawn(move || {
                    for i in 0..1000u64 {
                        h.record(t * 1000 + i);
                    }
                });
            }
        });
        assert_eq!(h.count(), 4000);
        assert_eq!(h.max(), 3999);
    }

    #[test]
    fn labeled_render_tags_every_series() {
        let m = ServeMetrics::new();
        m.requests.fetch_add(2, Ordering::Relaxed);
        m.latency_us.record(900);
        let text = m.render_prometheus_with(Some(("model", "alpha")));
        assert!(text.contains("pgpr_requests_total{model=\"alpha\"} 2"));
        assert!(text.contains("pgpr_request_latency_seconds{model=\"alpha\",quantile=\"0.99\"}"));
        assert!(text.contains("pgpr_request_latency_seconds_count{model=\"alpha\"} 1"));
        // Unlabeled stays in the legacy format.
        let plain = m.render_prometheus();
        assert!(plain.contains("pgpr_requests_total 2"));
        assert!(plain.contains("pgpr_request_latency_seconds{quantile=\"0.99\"}"));
    }

    #[test]
    fn stage_series_render_only_when_touched() {
        let m = ServeMetrics::new();
        m.stages.record(Stage::QueueWait, 0.0015);
        let mut set = StageSet::new();
        set.add(Stage::Serialize, 0.0002);
        m.stages.record_set(&set);
        let text = m.render_prometheus_with(Some(("model", "a")));
        assert!(
            text.contains("pgpr_stage_seconds{model=\"a\",stage=\"queue_wait\",quantile=\"0.5\"}"),
            "{text}"
        );
        assert!(text.contains("pgpr_stage_seconds_count{model=\"a\",stage=\"serialize\"} 1"));
        assert!(!text.contains("stage=\"f32u\""), "untouched stages must not render");
        let j = m.to_json();
        let stages = j.req("stages_s").unwrap();
        assert_eq!(
            stages.get("queue_wait").unwrap().get("count").unwrap().as_usize(),
            Some(1)
        );
        assert!(stages.get("f32u").is_none());
    }

    #[test]
    fn shed_and_restart_counters_render_and_json() {
        let m = ServeMetrics::new();
        m.record_shed(ShedReason::Slo);
        m.record_shed(ShedReason::Slo);
        m.record_shed(ShedReason::QueueFull);
        m.batcher_restarts.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.shed_total(), 3);
        let text = m.render_prometheus_with(Some(("model", "a")));
        assert!(text.contains("pgpr_requests_shed_total{model=\"a\",reason=\"slo\"} 2"), "{text}");
        assert!(text.contains("pgpr_requests_shed_total{model=\"a\",reason=\"queue_full\"} 1"));
        assert!(
            text.contains("pgpr_requests_shed_total{model=\"a\",reason=\"deadline\"} 0"),
            "zero-valued reasons still render"
        );
        assert!(text.contains("pgpr_batcher_restarts_total{model=\"a\"} 1"));
        let j = m.to_json();
        let shed = j.req("shed").unwrap();
        assert_eq!(shed.get("slo").unwrap().as_usize(), Some(2));
        assert_eq!(shed.get("shutdown").unwrap().as_usize(), Some(0));
        assert_eq!(j.req("batcher_restarts").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn process_uptime_monotone_and_build_info_sane() {
        process_start();
        let a = process_uptime_secs();
        let b = process_uptime_secs();
        assert!(a >= 0.0 && b >= a, "uptime went backwards: {a} -> {b}");
        let (version, features) = build_info();
        assert_eq!(version, env!("CARGO_PKG_VERSION"));
        assert!(features == "simd" || features == "default");
    }

    #[test]
    fn serve_metrics_render_and_json() {
        let m = ServeMetrics::new();
        m.requests.fetch_add(5, Ordering::Relaxed);
        m.responses.fetch_add(5, Ordering::Relaxed);
        m.batches.fetch_add(2, Ordering::Relaxed);
        m.latency_us.record(1500);
        m.batch_rows.record(3);
        m.batch_rows.record(2);
        let text = m.render_prometheus();
        assert!(text.contains("pgpr_requests_total 5"));
        assert!(text.contains("pgpr_request_latency_seconds{quantile=\"0.99\"}"));
        assert!(text.contains("pgpr_batch_occupancy_rows"));
        let j = m.to_json();
        assert_eq!(j.req("responses").unwrap().as_usize(), Some(5));
        assert!(j.req("latency_s").unwrap().get("p99").unwrap().as_f64().unwrap() > 0.0);
        let s = m.summary();
        assert!(s.contains("served 5 rows in 2 batches"));
    }
}
