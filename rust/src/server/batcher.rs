//! Micro-batching scheduler: decouples connection threads from the model.
//!
//! Connection workers call [`BatcherHandle::submit`], which validates the
//! rows, pushes them into a **bounded** MPSC queue (backpressure: a full
//! queue is an immediate `Overloaded`, not an unbounded pile-up) and
//! blocks on a per-request reply channel. A single dedicated batcher
//! thread owns the [`PredictionService`] and loops:
//!
//! 1. wait for the next request — but only until the service's
//!    [`deadline`](PredictionService::deadline) (oldest queued request +
//!    `max_delay`);
//! 2. on arrival, enqueue its rows — the service flushes itself when
//!    `batch_size` rows are queued;
//! 3. on deadline expiry, flush the partial batch, so a lone request is
//!    answered within `max_delay` instead of waiting for a full batch.
//!
//! Every answered row is routed back to the waiting connection through
//! its reply channel; a request spanning a batch boundary is completed
//! when its last row is answered. Each submitted request is answered
//! exactly once (a reply or an error), including at shutdown: when all
//! handles drop, the thread drains the queue, flushes and exits.
//!
//! The loop runs under a **supervisor**: a panic anywhere inside it
//! (engine bug, injected `batcher_panic` fault) is caught with
//! `catch_unwind`, every in-flight waiter is failed with a 503-mapped
//! error (the exactly-once invariant holds — one reply each, just an
//! unhappy one), the service is rebuilt around the shared engine, and
//! the loop respawns after a bounded exponential backoff. `/readyz`
//! reads the `running` flag, which is false only during the backoff
//! window, so external health checks see the outage and the recovery.
//!
//! Requests carry an optional **deadline** (`X-Deadline-Ms` /
//! `--default-deadline-ms`): one that has already expired when the
//! batcher dequeues it is failed in microseconds at batch-formation
//! time — its rows never reach the engine.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::service::{PredictionService, Request, Response};
use crate::obs::{log_event, Level, Stage, StageSet};
use crate::server::metrics::ServeMetrics;
use crate::util::error::{PgprError, Result};
use crate::util::fault;
use crate::util::json::Json;

/// One answered multi-row request.
#[derive(Clone, Debug)]
pub struct BatchReply {
    pub mean: Vec<f64>,
    pub var: Vec<f64>,
    /// Seconds between enqueue and the last row's batch completing.
    pub latency_s: f64,
    /// Per-stage breakdown (queue wait, batch formation, engine phases).
    /// All-zero when the service was built with tracing off.
    pub stages: StageSet,
}

/// Why a submit failed — mapped to HTTP status codes by the server.
#[derive(Clone, Debug)]
pub enum SubmitError {
    /// Malformed input (wrong dimension, empty, non-finite) → 400.
    BadRequest(String),
    /// The bounded queue is full → 503.
    Overloaded,
    /// The batcher has shut down → 503.
    Closed,
    /// The request's deadline expired before it reached the engine → 503
    /// (shed at batch formation, never computed).
    DeadlineExceeded,
    /// The batcher aborted the request (panic-triggered restart) → 503.
    Unavailable(String),
    /// The engine's predict call failed → 500.
    Engine(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::BadRequest(m) => write!(f, "bad request: {m}"),
            SubmitError::Overloaded => write!(f, "request queue is full"),
            SubmitError::Closed => write!(f, "service is shut down"),
            SubmitError::DeadlineExceeded => write!(f, "request deadline exceeded"),
            SubmitError::Unavailable(m) => write!(f, "service temporarily unavailable: {m}"),
            SubmitError::Engine(m) => write!(f, "prediction failed: {m}"),
        }
    }
}

/// The batcher's verdict routed back through a waiter's reply channel.
#[derive(Clone, Debug)]
enum ReplyError {
    /// The deadline expired before the rows reached the engine.
    Expired,
    /// The batcher restarted underneath the request.
    Aborted(String),
    /// The batcher drained and exited (all handles dropped).
    Shutdown,
    /// The engine's predict call failed.
    Failed(String),
}

type ReplyResult = std::result::Result<BatchReply, ReplyError>;

struct Incoming {
    rows: Vec<Vec<f64>>,
    reply: Sender<ReplyResult>,
    enqueued: Instant,
    /// Drop-dead instant propagated from the HTTP layer (`X-Deadline-Ms`
    /// / `--default-deadline-ms`); `None` = wait as long as it takes.
    deadline: Option<Instant>,
}

/// Cheap clonable submitter held by every connection worker.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: SyncSender<Incoming>,
    dim: usize,
    /// Requests currently sitting in the bounded queue (incremented on a
    /// successful enqueue, decremented when the batcher dequeues) — the
    /// depth whose saturation produces `Overloaded`/503.
    depth: Arc<AtomicU64>,
    metrics: Arc<ServeMetrics>,
    /// True while the batcher thread is inside its loop — cleared on any
    /// exit, including a panic (see `RunningGuard`). `/readyz` reads this.
    running: Arc<AtomicBool>,
}

impl BatcherHandle {
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Whether the batcher thread is still alive and serving.
    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::Relaxed)
    }

    /// Requests currently sitting in the bounded queue (the admission
    /// gate's queue-delay estimate reads this).
    pub fn depth(&self) -> u64 {
        self.depth.load(Ordering::Relaxed)
    }

    /// Submit one or more rows and block until the micro-batcher answers
    /// (bounded by `max_delay` plus one predict call).
    pub fn submit(&self, rows: Vec<Vec<f64>>) -> std::result::Result<BatchReply, SubmitError> {
        self.submit_with_deadline(rows, None)
    }

    /// [`submit`](Self::submit) with a drop-dead instant: if it passes
    /// before the rows reach the engine, the batcher sheds them at batch
    /// formation ([`SubmitError::DeadlineExceeded`]) instead of
    /// computing a prediction nobody is waiting for.
    pub fn submit_with_deadline(
        &self,
        rows: Vec<Vec<f64>>,
        deadline: Option<Instant>,
    ) -> std::result::Result<BatchReply, SubmitError> {
        if rows.is_empty() {
            return Err(SubmitError::BadRequest("no input rows".into()));
        }
        for r in &rows {
            if r.len() != self.dim {
                return Err(SubmitError::BadRequest(format!(
                    "row has dim {}, model expects {}",
                    r.len(),
                    self.dim
                )));
            }
            if r.iter().any(|v| !v.is_finite()) {
                return Err(SubmitError::BadRequest("non-finite input value".into()));
            }
        }
        let (rtx, rrx) = std::sync::mpsc::channel();
        let inc = Incoming { rows, reply: rtx, enqueued: Instant::now(), deadline };
        // Increment BEFORE try_send (and undo on failure): once the send
        // succeeds the batcher may dequeue-and-decrement at any moment,
        // and a decrement racing ahead of our increment would wrap the
        // counter to u64::MAX.
        let d = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        match self.tx.try_send(inc) {
            Ok(()) => self.metrics.queue_depth.record(d),
            Err(TrySendError::Full(_)) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                return Err(SubmitError::Overloaded);
            }
            Err(TrySendError::Disconnected(_)) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                return Err(SubmitError::Closed);
            }
        }
        match rrx.recv() {
            Ok(Ok(rep)) => Ok(rep),
            Ok(Err(ReplyError::Expired)) => Err(SubmitError::DeadlineExceeded),
            Ok(Err(ReplyError::Aborted(msg))) => Err(SubmitError::Unavailable(msg)),
            Ok(Err(ReplyError::Shutdown)) => Err(SubmitError::Closed),
            Ok(Err(ReplyError::Failed(msg))) => Err(SubmitError::Engine(msg)),
            // Sender dropped without a verdict (e.g. the request was lost
            // inside an unwinding batcher before it was registered).
            Err(_) => Err(SubmitError::Unavailable("batcher restarted".into())),
        }
    }
}

/// A request waiting for all of its rows to be answered.
struct Waiter {
    reply: Sender<ReplyResult>,
    enqueued: Instant,
    remaining: usize,
    mean: Vec<f64>,
    var: Vec<f64>,
    /// Seconds the request sat in the bounded queue before dequeue.
    queue_wait_s: f64,
    /// Engine stage times, merged once per answering batch.
    stages: StageSet,
    /// Worst batch-formation wait across this request's rows.
    batch_form_max: f64,
    /// Last batch sequence merged into `stages` (0 = none / tracing off),
    /// so a request spanning batches counts each batch's engine time once.
    last_batch: u64,
}

/// Clears the handle-visible `running` flag when the batcher thread
/// exits its loop — on a clean drain *or* an unwind.
struct RunningGuard(Arc<AtomicBool>);

impl Drop for RunningGuard {
    fn drop(&mut self) {
        self.0.store(false, Ordering::Relaxed);
    }
}

/// Spawn the supervised batcher thread over a configured service (batch
/// size and `max_delay` are the service's own). Returns the submit
/// handle and the thread's join handle; the thread exits after all
/// handles drop and the queue is drained.
pub fn spawn(
    svc: PredictionService,
    queue_capacity: usize,
) -> Result<(BatcherHandle, JoinHandle<()>)> {
    spawn_named(svc, queue_capacity, "default")
}

/// [`spawn`] with a model label for the `batcher_restarted` log event
/// (the registry passes the model name).
pub fn spawn_named(
    svc: PredictionService,
    queue_capacity: usize,
    label: &str,
) -> Result<(BatcherHandle, JoinHandle<()>)> {
    let dim = svc.dim();
    let metrics = svc.metrics();
    let depth = Arc::new(AtomicU64::new(0));
    let depth_rx = Arc::clone(&depth);
    let running = Arc::new(AtomicBool::new(true));
    let running_rx = Arc::clone(&running);
    let (tx, rx) = sync_channel::<Incoming>(queue_capacity.max(1));
    let label = label.to_string();
    let join = std::thread::Builder::new()
        .name("pgpr-batcher".into())
        .spawn(move || {
            let _prof = crate::obs::prof::register_thread(&format!("batcher-{label}"));
            let _guard = RunningGuard(Arc::clone(&running_rx));
            supervise(svc, rx, depth_rx, running_rx, &label);
        })
        .map_err(|e| PgprError::Io(format!("spawn batcher thread: {e}")))?;
    Ok((BatcherHandle { tx, dim, depth, metrics, running }, join))
}

/// Shortest backoff after the first panic; doubles per consecutive
/// restart up to [`MAX_BACKOFF`].
const BASE_BACKOFF: Duration = Duration::from_millis(20);
const MAX_BACKOFF: Duration = Duration::from_secs(2);

/// Run the batcher loop under `catch_unwind`, respawning it (same
/// thread, fresh service) after a panic. In-flight waiters are failed
/// with a 503-mapped error — every request still gets exactly one reply
/// — and requests parked in the bounded queue during the backoff window
/// survive to be served by the restarted loop.
fn supervise(
    svc: PredictionService,
    rx: Receiver<Incoming>,
    depth: Arc<AtomicU64>,
    running: Arc<AtomicBool>,
    label: &str,
) {
    // Everything needed to rebuild the service after a panic (the
    // panicked instance may be mid-mutation, so it is discarded).
    let engine = svc.shared_engine();
    let metrics = svc.metrics();
    let batch_size = svc.batch_size();
    let max_delay = svc.max_delay();
    let mode = svc.predict_mode();
    let trace = svc.trace();

    let mut state = LoopState::new();
    let mut svc_slot = Some(svc);
    let mut restarts: u32 = 0;
    loop {
        let mut svc = match svc_slot.take() {
            Some(s) => s,
            None => {
                let rebuilt = PredictionService::with_shared_metrics(
                    Arc::clone(&engine),
                    batch_size,
                    Arc::clone(&metrics),
                )
                .map(|s| {
                    let s = s.with_predict_mode(mode).with_trace(trace);
                    match max_delay {
                        Some(d) => s.with_max_delay(d),
                        None => s,
                    }
                });
                match rebuilt {
                    Ok(s) => s,
                    Err(e) => {
                        // Can't happen with a previously-valid config,
                        // but never loop on a broken rebuild.
                        log_event(
                            Level::Info,
                            "batcher_rebuild_failed",
                            vec![
                                ("model", Json::Str(label.to_string())),
                                ("error", Json::Str(e.to_string())),
                            ],
                        );
                        fail_all(&mut state.waiters, &mut state.routes, &ReplyError::Shutdown);
                        return;
                    }
                }
            }
        };
        running.store(true, Ordering::Relaxed);
        let outcome =
            catch_unwind(AssertUnwindSafe(|| run_loop(&mut svc, &rx, &depth, &mut state)));
        match outcome {
            Ok(()) => break, // clean drain: all handles dropped
            Err(payload) => {
                running.store(false, Ordering::Relaxed);
                let msg = panic_message(&payload);
                fail_all(&mut state.waiters, &mut state.routes, &ReplyError::Aborted(msg.clone()));
                metrics.batcher_restarts.fetch_add(1, Ordering::Relaxed);
                let backoff = BASE_BACKOFF
                    .saturating_mul(1u32 << restarts.min(10))
                    .min(MAX_BACKOFF);
                restarts = restarts.saturating_add(1);
                log_event(
                    Level::Info,
                    "batcher_restarted",
                    vec![
                        ("model", Json::Str(label.to_string())),
                        ("restarts", Json::Num(restarts as f64)),
                        ("backoff_ms", Json::Num(backoff.as_millis() as f64)),
                        ("panic", Json::Str(msg)),
                    ],
                );
                std::thread::sleep(backoff);
            }
        }
    }
    // Anything still waiting (e.g. after an engine failure) gets closed out.
    fail_all(&mut state.waiters, &mut state.routes, &ReplyError::Shutdown);
}

/// Best-effort text of a panic payload (what `panic!` carried).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "batcher panicked".to_string()
    }
}

/// Reply bookkeeping that must survive a panic inside [`run_loop`]: it
/// lives in the supervisor's frame, outside the unwind boundary, so the
/// supervisor can fail every registered waiter explicitly.
struct LoopState {
    waiters: HashMap<u64, Waiter>,
    /// Service request id → (waiter key, row slot within the waiter).
    routes: HashMap<u64, (u64, usize)>,
    next_id: u64,
    next_waiter: u64,
}

impl LoopState {
    fn new() -> LoopState {
        LoopState {
            waiters: HashMap::new(),
            routes: HashMap::new(),
            next_id: 0,
            next_waiter: 0,
        }
    }
}

fn run_loop(
    svc: &mut PredictionService,
    rx: &Receiver<Incoming>,
    depth: &AtomicU64,
    state: &mut LoopState,
) {
    let metrics = svc.metrics();
    let tracing = svc.trace();
    let mut open = true;
    while open || svc.queued_rows() > 0 {
        let msg = match svc.deadline() {
            // Nothing queued (or no max_delay): block for the next request.
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => {
                    open = false;
                    None
                }
            },
            Some(dl) => {
                let wait = dl.saturating_duration_since(Instant::now());
                if wait.is_zero() {
                    None // deadline already expired: flush below
                } else {
                    match rx.recv_timeout(wait) {
                        Ok(m) => Some(m),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => {
                            open = false;
                            None
                        }
                    }
                }
            }
        };
        let mut answered: Vec<Response> = Vec::new();
        let mut failure: Option<String> = None;
        match msg {
            Some(inc) => {
                depth.fetch_sub(1, Ordering::Relaxed);
                // Chaos hooks: a stuck queue stalls batch formation; an
                // armed panic exercises the supervisor's restart path.
                fault::stall(fault::QUEUE_STICK);
                if fault::fire(fault::BATCHER_PANIC).is_some() {
                    panic!("injected fault: batcher_panic");
                }
                // Batch-formation deadline check: an expired request is
                // shed here, in microseconds — its rows never reach the
                // engine (counted as a `deadline` shed at the HTTP
                // boundary, where the error is mapped to a 503).
                if inc.deadline.is_some_and(|dl| Instant::now() >= dl) {
                    let _ = inc.reply.send(Err(ReplyError::Expired));
                    continue;
                }
                let queue_wait_s = if tracing {
                    let qw = inc.enqueued.elapsed().as_secs_f64();
                    metrics.stages.record(Stage::QueueWait, qw);
                    qw
                } else {
                    0.0
                };
                let wkey = state.next_waiter;
                state.next_waiter += 1;
                let n = inc.rows.len();
                state.waiters.insert(
                    wkey,
                    Waiter {
                        reply: inc.reply,
                        enqueued: inc.enqueued,
                        remaining: n,
                        mean: vec![0.0; n],
                        var: vec![0.0; n],
                        queue_wait_s,
                        stages: StageSet::new(),
                        batch_form_max: 0.0,
                        last_batch: 0,
                    },
                );
                for (slot, row) in inc.rows.into_iter().enumerate() {
                    state.next_id += 1;
                    state.routes.insert(state.next_id, (wkey, slot));
                    match svc.submit(Request { id: state.next_id, x: row }) {
                        Ok(resp) => answered.extend(resp),
                        Err(e) => {
                            failure = Some(e.to_string());
                            break;
                        }
                    }
                }
            }
            None => match svc.flush() {
                Ok(resp) => answered.extend(resp),
                Err(e) => failure = Some(e.to_string()),
            },
        }
        // Deliver completed predictions first so a failure only affects
        // the requests that are genuinely still unanswered.
        deliver(answered, &mut state.waiters, &mut state.routes);
        if let Some(m) = failure {
            fail_all(&mut state.waiters, &mut state.routes, &ReplyError::Failed(m));
        }
    }
}

fn deliver(
    answered: Vec<Response>,
    waiters: &mut HashMap<u64, Waiter>,
    routes: &mut HashMap<u64, (u64, usize)>,
) {
    for resp in answered {
        let (wkey, slot) = match routes.remove(&resp.id) {
            Some(r) => r,
            None => continue,
        };
        let done = {
            let w = waiters.get_mut(&wkey).expect("waiter exists for routed id");
            w.mean[slot] = resp.mean;
            w.var[slot] = resp.var;
            // Engine stage times are per *batch*: merge them once per
            // answering batch, not once per row, or a multi-row request
            // answered by one batch would count the engine N times.
            if resp.batch != 0 && resp.batch != w.last_batch {
                w.stages.merge(&resp.stages);
                w.last_batch = resp.batch;
            }
            if resp.batch_form_s > w.batch_form_max {
                w.batch_form_max = resp.batch_form_s;
            }
            w.remaining -= 1;
            w.remaining == 0
        };
        if done {
            let w = waiters.remove(&wkey).expect("completed waiter present");
            let latency_s = w.enqueued.elapsed().as_secs_f64();
            let mut stages = w.stages;
            if w.queue_wait_s > 0.0 {
                stages.add(Stage::QueueWait, w.queue_wait_s);
            }
            if w.batch_form_max > 0.0 {
                stages.add(Stage::BatchForm, w.batch_form_max);
            }
            // Receiver may have given up (connection dropped): ignore.
            let _ = w.reply.send(Ok(BatchReply { mean: w.mean, var: w.var, latency_s, stages }));
        }
    }
}

/// Fail every still-waiting request. Error *counting* happens at the
/// HTTP boundary (one per failed response), so this only routes the
/// verdict — no metrics here, or engine failures would double-count.
fn fail_all(
    waiters: &mut HashMap<u64, Waiter>,
    routes: &mut HashMap<u64, (u64, usize)>,
    err: &ReplyError,
) {
    for (_, w) in waiters.drain() {
        let _ = w.reply.send(Err(err.clone()));
    }
    routes.clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LmaConfig, PartitionStrategy};
    use crate::kernels::se_ard::SeArdHyper;
    use crate::linalg::matrix::Mat;
    use crate::lma::LmaRegressor;
    use crate::util::rng::Pcg64;
    use std::time::Duration;

    fn fitted() -> LmaRegressor {
        let mut rng = Pcg64::new(77);
        let hyp = SeArdHyper::isotropic(1, 1.0, 1.0, 0.1);
        let x = Mat::col_vec(&rng.uniform_vec(140, -4.0, 4.0));
        let y: Vec<f64> = (0..140).map(|i| x.get(i, 0).sin()).collect();
        let cfg = LmaConfig {
            num_blocks: 4,
            markov_order: 1,
            support_size: 24,
            seed: 1,
            partition: PartitionStrategy::KMeans { iters: 6 },
            use_pjrt: false,
        };
        LmaRegressor::fit(&x, &y, &hyp, &cfg).unwrap()
    }

    fn batcher(batch: usize, delay_us: u64) -> (BatcherHandle, JoinHandle<()>, LmaRegressor) {
        let model = fitted();
        let svc = PredictionService::new(fitted(), batch)
            .unwrap()
            .with_max_delay(Duration::from_micros(delay_us));
        let (h, j) = spawn(svc, 64).unwrap();
        (h, j, model)
    }

    #[test]
    fn lone_request_is_answered_within_deadline() {
        // Huge batch size: only the deadline can flush.
        let (h, j, model) = batcher(1000, 2000);
        let t0 = Instant::now();
        let rep = h.submit(vec![vec![0.5]]).unwrap();
        // Generous bound (CI machines are slow), but proves it didn't
        // strand forever waiting for 1000 rows.
        assert!(t0.elapsed() < Duration::from_secs(10));
        let direct = model.predict(&Mat::col_vec(&[0.5])).unwrap();
        assert_eq!(rep.mean[0].to_bits(), direct.mean[0].to_bits());
        assert_eq!(rep.var[0].to_bits(), direct.var[0].to_bits());
        drop(h);
        j.join().unwrap();
    }

    #[test]
    fn multi_row_request_is_answered_in_order() {
        let (h, j, model) = batcher(4, 1000);
        let rows: Vec<Vec<f64>> = vec![vec![-1.0], vec![0.0], vec![1.0]];
        let rep = h.submit(rows).unwrap();
        assert_eq!(rep.mean.len(), 3);
        for (i, q) in [-1.0, 0.0, 1.0].iter().enumerate() {
            let direct = model.predict(&Mat::col_vec(&[*q])).unwrap();
            assert_eq!(rep.mean[i].to_bits(), direct.mean[0].to_bits(), "row {i}");
        }
        drop(h);
        j.join().unwrap();
    }

    #[test]
    fn bad_rows_rejected_before_queueing() {
        let (h, j, _model) = batcher(4, 1000);
        assert!(matches!(h.submit(vec![]), Err(SubmitError::BadRequest(_))));
        assert!(matches!(h.submit(vec![vec![0.0, 1.0]]), Err(SubmitError::BadRequest(_))));
        assert!(matches!(h.submit(vec![vec![f64::NAN]]), Err(SubmitError::BadRequest(_))));
        // A good request still works afterwards.
        assert!(h.submit(vec![vec![0.2]]).is_ok());
        drop(h);
        j.join().unwrap();
    }

    #[test]
    fn concurrent_submitters_each_answered_exactly_once() {
        let (h, j, model) = batcher(3, 1500);
        let queries: Vec<f64> = (0..24).map(|i| -3.0 + 0.25 * i as f64).collect();
        let results: Vec<(usize, f64)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..6)
                .map(|w| {
                    let h = h.clone();
                    let queries = &queries;
                    s.spawn(move || {
                        let mut out = Vec::new();
                        for i in (w..queries.len()).step_by(6) {
                            let rep = h.submit(vec![vec![queries[i]]]).unwrap();
                            out.push((i, rep.mean[0]));
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().flat_map(|t| t.join().unwrap()).collect()
        });
        assert_eq!(results.len(), queries.len());
        for (i, mean) in results {
            let direct = model.predict(&Mat::col_vec(&[queries[i]])).unwrap();
            assert_eq!(mean.to_bits(), direct.mean[0].to_bits(), "query {i}");
        }
        drop(h);
        j.join().unwrap();
    }

    #[test]
    fn replies_carry_stage_breakdowns_and_running_clears_on_exit() {
        let (h, j, _model) = batcher(4, 1000);
        assert!(h.is_running());
        let rep = h.submit(vec![vec![-0.5], vec![0.5]]).unwrap();
        assert!(rep.stages.sum() > 0.0, "traced reply must carry a stage breakdown");
        assert!(
            rep.stages.get(Stage::QueueWait) > 0.0,
            "queue wait is recorded at dequeue (monotonic clock, > 0)"
        );
        // The attributed stages can never exceed the end-to-end latency by
        // more than timer noise.
        assert!(
            rep.stages.sum() <= rep.latency_s * 1.5 + 1e-3,
            "stages {} vs latency {}",
            rep.stages.sum(),
            rep.latency_s
        );
        let running = Arc::clone(&h.running);
        drop(h);
        j.join().unwrap();
        assert!(!running.load(Ordering::Relaxed), "guard clears the flag on exit");
    }

    #[test]
    fn shutdown_drains_and_joins() {
        let (h, j, _model) = batcher(100, 50_000);
        let rep = h.submit(vec![vec![0.1]]).unwrap();
        assert_eq!(rep.mean.len(), 1);
        drop(h);
        j.join().unwrap();
    }

    #[test]
    fn expired_deadline_is_shed_before_the_engine() {
        let (h, j, _model) = batcher(4, 1000);
        let engine_batches_before = h.metrics.batches.load(Ordering::Relaxed);
        // A deadline already in the past: shed at batch formation.
        let expired = Instant::now() - Duration::from_millis(5);
        let r = h.submit_with_deadline(vec![vec![0.3]], Some(expired));
        assert!(matches!(r, Err(SubmitError::DeadlineExceeded)), "got {r:?}");
        assert_eq!(
            h.metrics.batches.load(Ordering::Relaxed),
            engine_batches_before,
            "expired request must never reach the engine"
        );
        // A generous deadline is honored normally.
        let far = Instant::now() + Duration::from_secs(30);
        let ok = h.submit_with_deadline(vec![vec![0.3]], Some(far));
        assert!(ok.is_ok());
        drop(h);
        j.join().unwrap();
    }

    #[test]
    fn injected_panic_restarts_the_loop_and_loses_nothing() {
        let _g = crate::util::fault::serial_guard();
        crate::util::fault::reset();
        let (h, j, model) = batcher(4, 1000);
        crate::util::fault::arm(crate::util::fault::BATCHER_PANIC, 1);
        // The victim request is answered exactly once — with a 503-mapped
        // error, not silence.
        let r = h.submit(vec![vec![0.1]]);
        assert!(
            matches!(r, Err(SubmitError::Unavailable(_)) | Err(SubmitError::Closed)),
            "victim gets an explicit failure, got {r:?}"
        );
        // The supervisor respawns the loop; a subsequent request succeeds
        // and answers bit-identically to the direct engine.
        let mut rep = None;
        for _ in 0..100 {
            match h.submit(vec![vec![0.5]]) {
                Ok(r) => {
                    rep = Some(r);
                    break;
                }
                Err(_) => std::thread::sleep(Duration::from_millis(10)),
            }
        }
        let rep = rep.expect("batcher recovered within 1s");
        let direct = model.predict(&Mat::col_vec(&[0.5])).unwrap();
        assert_eq!(rep.mean[0].to_bits(), direct.mean[0].to_bits());
        assert!(h.is_running(), "running flag flips back after respawn");
        assert_eq!(h.metrics.batcher_restarts.load(Ordering::Relaxed), 1);
        crate::util::fault::reset();
        drop(h);
        j.join().unwrap();
    }
}
