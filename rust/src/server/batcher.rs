//! Micro-batching scheduler: decouples connection threads from the model.
//!
//! Connection workers call [`BatcherHandle::submit`], which validates the
//! rows, pushes them into a **bounded** MPSC queue (backpressure: a full
//! queue is an immediate `Overloaded`, not an unbounded pile-up) and
//! blocks on a per-request reply channel. A single dedicated batcher
//! thread owns the [`PredictionService`] and loops:
//!
//! 1. wait for the next request — but only until the service's
//!    [`deadline`](PredictionService::deadline) (oldest queued request +
//!    `max_delay`);
//! 2. on arrival, enqueue its rows — the service flushes itself when
//!    `batch_size` rows are queued;
//! 3. on deadline expiry, flush the partial batch, so a lone request is
//!    answered within `max_delay` instead of waiting for a full batch.
//!
//! Every answered row is routed back to the waiting connection through
//! its reply channel; a request spanning a batch boundary is completed
//! when its last row is answered. Each submitted request is answered
//! exactly once (a reply or an error), including at shutdown: when all
//! handles drop, the thread drains the queue, flushes and exits.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::service::{PredictionService, Request, Response};
use crate::obs::{Stage, StageSet};
use crate::server::metrics::ServeMetrics;
use crate::util::error::{PgprError, Result};

/// One answered multi-row request.
#[derive(Clone, Debug)]
pub struct BatchReply {
    pub mean: Vec<f64>,
    pub var: Vec<f64>,
    /// Seconds between enqueue and the last row's batch completing.
    pub latency_s: f64,
    /// Per-stage breakdown (queue wait, batch formation, engine phases).
    /// All-zero when the service was built with tracing off.
    pub stages: StageSet,
}

/// Why a submit failed — mapped to HTTP status codes by the server.
#[derive(Clone, Debug)]
pub enum SubmitError {
    /// Malformed input (wrong dimension, empty, non-finite) → 400.
    BadRequest(String),
    /// The bounded queue is full → 503.
    Overloaded,
    /// The batcher has shut down → 503.
    Closed,
    /// The engine's predict call failed → 500.
    Engine(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::BadRequest(m) => write!(f, "bad request: {m}"),
            SubmitError::Overloaded => write!(f, "request queue is full"),
            SubmitError::Closed => write!(f, "service is shut down"),
            SubmitError::Engine(m) => write!(f, "prediction failed: {m}"),
        }
    }
}

type ReplyResult = std::result::Result<BatchReply, String>;

struct Incoming {
    rows: Vec<Vec<f64>>,
    reply: Sender<ReplyResult>,
    enqueued: Instant,
}

/// Cheap clonable submitter held by every connection worker.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: SyncSender<Incoming>,
    dim: usize,
    /// Requests currently sitting in the bounded queue (incremented on a
    /// successful enqueue, decremented when the batcher dequeues) — the
    /// depth whose saturation produces `Overloaded`/503.
    depth: Arc<AtomicU64>,
    metrics: Arc<ServeMetrics>,
    /// True while the batcher thread is inside its loop — cleared on any
    /// exit, including a panic (see `RunningGuard`). `/readyz` reads this.
    running: Arc<AtomicBool>,
}

impl BatcherHandle {
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Whether the batcher thread is still alive and serving.
    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::Relaxed)
    }

    /// Submit one or more rows and block until the micro-batcher answers
    /// (bounded by `max_delay` plus one predict call).
    pub fn submit(&self, rows: Vec<Vec<f64>>) -> std::result::Result<BatchReply, SubmitError> {
        if rows.is_empty() {
            return Err(SubmitError::BadRequest("no input rows".into()));
        }
        for r in &rows {
            if r.len() != self.dim {
                return Err(SubmitError::BadRequest(format!(
                    "row has dim {}, model expects {}",
                    r.len(),
                    self.dim
                )));
            }
            if r.iter().any(|v| !v.is_finite()) {
                return Err(SubmitError::BadRequest("non-finite input value".into()));
            }
        }
        let (rtx, rrx) = std::sync::mpsc::channel();
        let inc = Incoming { rows, reply: rtx, enqueued: Instant::now() };
        // Increment BEFORE try_send (and undo on failure): once the send
        // succeeds the batcher may dequeue-and-decrement at any moment,
        // and a decrement racing ahead of our increment would wrap the
        // counter to u64::MAX.
        let d = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        match self.tx.try_send(inc) {
            Ok(()) => self.metrics.queue_depth.record(d),
            Err(TrySendError::Full(_)) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                return Err(SubmitError::Overloaded);
            }
            Err(TrySendError::Disconnected(_)) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                return Err(SubmitError::Closed);
            }
        }
        match rrx.recv() {
            Ok(Ok(rep)) => Ok(rep),
            Ok(Err(msg)) => Err(SubmitError::Engine(msg)),
            Err(_) => Err(SubmitError::Closed),
        }
    }
}

/// A request waiting for all of its rows to be answered.
struct Waiter {
    reply: Sender<ReplyResult>,
    enqueued: Instant,
    remaining: usize,
    mean: Vec<f64>,
    var: Vec<f64>,
    /// Seconds the request sat in the bounded queue before dequeue.
    queue_wait_s: f64,
    /// Engine stage times, merged once per answering batch.
    stages: StageSet,
    /// Worst batch-formation wait across this request's rows.
    batch_form_max: f64,
    /// Last batch sequence merged into `stages` (0 = none / tracing off),
    /// so a request spanning batches counts each batch's engine time once.
    last_batch: u64,
}

/// Clears the handle-visible `running` flag when the batcher thread
/// exits its loop — on a clean drain *or* an unwind.
struct RunningGuard(Arc<AtomicBool>);

impl Drop for RunningGuard {
    fn drop(&mut self) {
        self.0.store(false, Ordering::Relaxed);
    }
}

/// Spawn the batcher thread over a configured service (batch size and
/// `max_delay` are the service's own). Returns the submit handle and the
/// thread's join handle; the thread exits after all handles drop and the
/// queue is drained.
pub fn spawn(
    svc: PredictionService,
    queue_capacity: usize,
) -> Result<(BatcherHandle, JoinHandle<()>)> {
    let dim = svc.dim();
    let metrics = svc.metrics();
    let depth = Arc::new(AtomicU64::new(0));
    let depth_rx = Arc::clone(&depth);
    let running = Arc::new(AtomicBool::new(true));
    let running_rx = Arc::clone(&running);
    let (tx, rx) = sync_channel::<Incoming>(queue_capacity.max(1));
    let join = std::thread::Builder::new()
        .name("pgpr-batcher".into())
        .spawn(move || {
            let _guard = RunningGuard(running_rx);
            run_loop(svc, rx, depth_rx);
        })
        .map_err(|e| PgprError::Io(format!("spawn batcher thread: {e}")))?;
    Ok((BatcherHandle { tx, dim, depth, metrics, running }, join))
}

fn run_loop(mut svc: PredictionService, rx: Receiver<Incoming>, depth: Arc<AtomicU64>) {
    let metrics = svc.metrics();
    let tracing = svc.trace();
    let mut waiters: HashMap<u64, Waiter> = HashMap::new();
    // Service request id → (waiter key, row slot within the waiter).
    let mut routes: HashMap<u64, (u64, usize)> = HashMap::new();
    let mut next_id: u64 = 0;
    let mut next_waiter: u64 = 0;
    let mut open = true;
    while open || svc.queued_rows() > 0 {
        let msg = match svc.deadline() {
            // Nothing queued (or no max_delay): block for the next request.
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => {
                    open = false;
                    None
                }
            },
            Some(dl) => {
                let wait = dl.saturating_duration_since(Instant::now());
                if wait.is_zero() {
                    None // deadline already expired: flush below
                } else {
                    match rx.recv_timeout(wait) {
                        Ok(m) => Some(m),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => {
                            open = false;
                            None
                        }
                    }
                }
            }
        };
        let mut answered: Vec<Response> = Vec::new();
        let mut failure: Option<String> = None;
        match msg {
            Some(inc) => {
                depth.fetch_sub(1, Ordering::Relaxed);
                let queue_wait_s = if tracing {
                    let qw = inc.enqueued.elapsed().as_secs_f64();
                    metrics.stages.record(Stage::QueueWait, qw);
                    qw
                } else {
                    0.0
                };
                let wkey = next_waiter;
                next_waiter += 1;
                let n = inc.rows.len();
                waiters.insert(
                    wkey,
                    Waiter {
                        reply: inc.reply,
                        enqueued: inc.enqueued,
                        remaining: n,
                        mean: vec![0.0; n],
                        var: vec![0.0; n],
                        queue_wait_s,
                        stages: StageSet::new(),
                        batch_form_max: 0.0,
                        last_batch: 0,
                    },
                );
                for (slot, row) in inc.rows.into_iter().enumerate() {
                    next_id += 1;
                    routes.insert(next_id, (wkey, slot));
                    match svc.submit(Request { id: next_id, x: row }) {
                        Ok(resp) => answered.extend(resp),
                        Err(e) => {
                            failure = Some(e.to_string());
                            break;
                        }
                    }
                }
            }
            None => match svc.flush() {
                Ok(resp) => answered.extend(resp),
                Err(e) => failure = Some(e.to_string()),
            },
        }
        // Deliver completed predictions first so a failure only affects
        // the requests that are genuinely still unanswered.
        deliver(answered, &mut waiters, &mut routes);
        if let Some(m) = failure {
            fail_all(&mut waiters, &mut routes, &m);
        }
    }
    // Anything still waiting (e.g. after an engine failure) gets closed out.
    fail_all(&mut waiters, &mut routes, "service shut down");
}

fn deliver(
    answered: Vec<Response>,
    waiters: &mut HashMap<u64, Waiter>,
    routes: &mut HashMap<u64, (u64, usize)>,
) {
    for resp in answered {
        let (wkey, slot) = match routes.remove(&resp.id) {
            Some(r) => r,
            None => continue,
        };
        let done = {
            let w = waiters.get_mut(&wkey).expect("waiter exists for routed id");
            w.mean[slot] = resp.mean;
            w.var[slot] = resp.var;
            // Engine stage times are per *batch*: merge them once per
            // answering batch, not once per row, or a multi-row request
            // answered by one batch would count the engine N times.
            if resp.batch != 0 && resp.batch != w.last_batch {
                w.stages.merge(&resp.stages);
                w.last_batch = resp.batch;
            }
            if resp.batch_form_s > w.batch_form_max {
                w.batch_form_max = resp.batch_form_s;
            }
            w.remaining -= 1;
            w.remaining == 0
        };
        if done {
            let w = waiters.remove(&wkey).expect("completed waiter present");
            let latency_s = w.enqueued.elapsed().as_secs_f64();
            let mut stages = w.stages;
            if w.queue_wait_s > 0.0 {
                stages.add(Stage::QueueWait, w.queue_wait_s);
            }
            if w.batch_form_max > 0.0 {
                stages.add(Stage::BatchForm, w.batch_form_max);
            }
            // Receiver may have given up (connection dropped): ignore.
            let _ = w.reply.send(Ok(BatchReply { mean: w.mean, var: w.var, latency_s, stages }));
        }
    }
}

/// Fail every still-waiting request. Error *counting* happens at the
/// HTTP boundary (one per failed response), so this only routes the
/// message — no metrics here, or engine failures would double-count.
fn fail_all(
    waiters: &mut HashMap<u64, Waiter>,
    routes: &mut HashMap<u64, (u64, usize)>,
    msg: &str,
) {
    for (_, w) in waiters.drain() {
        let _ = w.reply.send(Err(msg.to_string()));
    }
    routes.clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LmaConfig, PartitionStrategy};
    use crate::coordinator::service::ServeEngine;
    use crate::kernels::se_ard::SeArdHyper;
    use crate::linalg::matrix::Mat;
    use crate::lma::LmaRegressor;
    use crate::util::rng::Pcg64;
    use std::time::Duration;

    fn fitted() -> LmaRegressor {
        let mut rng = Pcg64::new(77);
        let hyp = SeArdHyper::isotropic(1, 1.0, 1.0, 0.1);
        let x = Mat::col_vec(&rng.uniform_vec(140, -4.0, 4.0));
        let y: Vec<f64> = (0..140).map(|i| x.get(i, 0).sin()).collect();
        let cfg = LmaConfig {
            num_blocks: 4,
            markov_order: 1,
            support_size: 24,
            seed: 1,
            partition: PartitionStrategy::KMeans { iters: 6 },
            use_pjrt: false,
        };
        LmaRegressor::fit(&x, &y, &hyp, &cfg).unwrap()
    }

    fn batcher(batch: usize, delay_us: u64) -> (BatcherHandle, JoinHandle<()>, LmaRegressor) {
        let model = fitted();
        let svc = PredictionService::new(fitted(), batch)
            .unwrap()
            .with_max_delay(Duration::from_micros(delay_us));
        let (h, j) = spawn(svc, 64).unwrap();
        (h, j, model)
    }

    #[test]
    fn lone_request_is_answered_within_deadline() {
        // Huge batch size: only the deadline can flush.
        let (h, j, model) = batcher(1000, 2000);
        let t0 = Instant::now();
        let rep = h.submit(vec![vec![0.5]]).unwrap();
        // Generous bound (CI machines are slow), but proves it didn't
        // strand forever waiting for 1000 rows.
        assert!(t0.elapsed() < Duration::from_secs(10));
        let direct = model.predict(&Mat::col_vec(&[0.5])).unwrap();
        assert_eq!(rep.mean[0].to_bits(), direct.mean[0].to_bits());
        assert_eq!(rep.var[0].to_bits(), direct.var[0].to_bits());
        drop(h);
        j.join().unwrap();
    }

    #[test]
    fn multi_row_request_is_answered_in_order() {
        let (h, j, model) = batcher(4, 1000);
        let rows: Vec<Vec<f64>> = vec![vec![-1.0], vec![0.0], vec![1.0]];
        let rep = h.submit(rows).unwrap();
        assert_eq!(rep.mean.len(), 3);
        for (i, q) in [-1.0, 0.0, 1.0].iter().enumerate() {
            let direct = model.predict(&Mat::col_vec(&[*q])).unwrap();
            assert_eq!(rep.mean[i].to_bits(), direct.mean[0].to_bits(), "row {i}");
        }
        drop(h);
        j.join().unwrap();
    }

    #[test]
    fn bad_rows_rejected_before_queueing() {
        let (h, j, _model) = batcher(4, 1000);
        assert!(matches!(h.submit(vec![]), Err(SubmitError::BadRequest(_))));
        assert!(matches!(h.submit(vec![vec![0.0, 1.0]]), Err(SubmitError::BadRequest(_))));
        assert!(matches!(h.submit(vec![vec![f64::NAN]]), Err(SubmitError::BadRequest(_))));
        // A good request still works afterwards.
        assert!(h.submit(vec![vec![0.2]]).is_ok());
        drop(h);
        j.join().unwrap();
    }

    #[test]
    fn concurrent_submitters_each_answered_exactly_once() {
        let (h, j, model) = batcher(3, 1500);
        let queries: Vec<f64> = (0..24).map(|i| -3.0 + 0.25 * i as f64).collect();
        let results: Vec<(usize, f64)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..6)
                .map(|w| {
                    let h = h.clone();
                    let queries = &queries;
                    s.spawn(move || {
                        let mut out = Vec::new();
                        for i in (w..queries.len()).step_by(6) {
                            let rep = h.submit(vec![vec![queries[i]]]).unwrap();
                            out.push((i, rep.mean[0]));
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().flat_map(|t| t.join().unwrap()).collect()
        });
        assert_eq!(results.len(), queries.len());
        for (i, mean) in results {
            let direct = model.predict(&Mat::col_vec(&[queries[i]])).unwrap();
            assert_eq!(mean.to_bits(), direct.mean[0].to_bits(), "query {i}");
        }
        drop(h);
        j.join().unwrap();
    }

    #[test]
    fn replies_carry_stage_breakdowns_and_running_clears_on_exit() {
        let (h, j, _model) = batcher(4, 1000);
        assert!(h.is_running());
        let rep = h.submit(vec![vec![-0.5], vec![0.5]]).unwrap();
        assert!(rep.stages.sum() > 0.0, "traced reply must carry a stage breakdown");
        assert!(
            rep.stages.get(Stage::QueueWait) > 0.0,
            "queue wait is recorded at dequeue (monotonic clock, > 0)"
        );
        // The attributed stages can never exceed the end-to-end latency by
        // more than timer noise.
        assert!(
            rep.stages.sum() <= rep.latency_s * 1.5 + 1e-3,
            "stages {} vs latency {}",
            rep.stages.sum(),
            rep.latency_s
        );
        let running = Arc::clone(&h.running);
        drop(h);
        j.join().unwrap();
        assert!(!running.load(Ordering::Relaxed), "guard clears the flag on exit");
    }

    #[test]
    fn shutdown_drains_and_joins() {
        let (h, j, _model) = batcher(100, 50_000);
        let rep = h.submit(vec![vec![0.1]]).unwrap();
        assert_eq!(rep.mean.len(), 1);
        drop(h);
        j.join().unwrap();
    }
}
