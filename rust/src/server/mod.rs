//! Network serving subsystem: a dependency-free HTTP/1.1 front end for
//! the fitted LMA engine.
//!
//! Layers (request path, top to bottom):
//!
//! * [`http`] — `std::net::TcpListener` server: one acceptor thread feeds
//!   a pool of connection workers speaking HTTP/1.1 keep-alive; routes
//!   `POST /predict` (JSON rows, optional `"model"` field) against the
//!   [`ModelRegistry`](crate::registry::ModelRegistry), plus
//!   `GET/PUT/DELETE /models[/name]` management, `GET /healthz` and
//!   `GET /metrics` (per-model labeled series).
//! * [`admission`] — the deadline-aware admission gate. Before a request
//!   is enqueued it is checked against the model's SLO (`--slo-ms`), its
//!   own deadline (`X-Deadline-Ms` / `--default-deadline-ms`) and the
//!   model's QoS share of the worker pool; overload degrades to fast
//!   `503 + Retry-After` sheds instead of timeout queues.
//! * [`batcher`] — the micro-batching scheduler. Connection workers hand
//!   requests into a bounded MPSC queue; a dedicated batcher thread owns
//!   the [`PredictionService`](crate::coordinator::service::PredictionService)
//!   and flushes when `batch_size` rows are queued **or** the oldest
//!   request's `max_delay` deadline expires, so a lone request is never
//!   stranded waiting for a full batch. Each waiting connection is
//!   answered through its own reply channel, exactly once — a supervisor
//!   wraps the loop in `catch_unwind` and respawns it (bounded
//!   exponential backoff) if it ever panics, failing the in-flight
//!   waiters with 503 rather than stranding them.
//! * [`metrics`] — lock-cheap atomic histograms (log-linear buckets) for
//!   request latency, per-batch occupancy and queue depth, reporting
//!   p50/p95/p99; rendered on `/metrics` and in the shutdown summary.
//! * [`loadgen`] — a multi-threaded closed-loop client that drives the
//!   server at fixed concurrency and produces the `BENCH_serve_latency`
//!   record (`pgpr loadtest`, `bench_serve_latency`).
//!
//! The engine behind the service is a
//! [`ServeEngine`](crate::coordinator::service::ServeEngine) — centralized
//! LMA or the cluster-parallel engine (`sim` / `threads[:N]`), so real
//! network traffic exercises the `cluster::Backend` layer end to end.

pub mod admission;
pub mod batcher;
pub mod http;
pub mod loadgen;
pub mod metrics;

pub use http::Server;
