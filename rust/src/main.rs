//! `pgpr` — leader entrypoint for the LMA reproduction.
//!
//! See `pgpr help` (or just `pgpr`) for subcommands — experiments, data
//! generation, CSV eval, the HTTP/stdin prediction service (`serve`) and
//! the closed-loop load generator (`loadtest`). The heavy lifting lives
//! in the `pgpr` library crate; this binary is a thin dispatcher.

/// Route every heap allocation through the tracking wrapper so
/// `/metrics` heap gauges and `/debug/prof` per-tag breakdowns reflect
/// real allocator traffic (relaxed atomic counters; see `obs::alloc`).
#[global_allocator]
static ALLOC: pgpr::obs::alloc::TrackingAlloc = pgpr::obs::alloc::TrackingAlloc;

fn main() {
    if let Err(e) = pgpr::coordinator::cli_run::dispatch() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
