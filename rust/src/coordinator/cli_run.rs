//! Subcommand implementations for the `pgpr` binary.

use std::io::BufRead;
use std::sync::Arc;
use std::time::Duration;

use crate::config::{
    BackendKind, ClusterConfig, LmaConfig, PartitionStrategy, RegistryOptions, ServeOptions,
};
use crate::coordinator::service::{PredictionService, Request, ServeEngine};
use crate::experiments::{ablation, common::Workload, fig2, fig6, table1, table2, table3};
use crate::lma::parallel::ParallelLma;
use crate::lma::{LmaRegressor, PredictMode};
use crate::obs::{log_event, Level, QualityBaseline, ScoreMode};
use crate::registry::{artifact, ModelRegistry};
use crate::server::admission::AdmissionPolicy;
use crate::server::http::Server;
use crate::server::loadgen;
use crate::util::cli::Args;
use crate::util::csv::CsvTable;
use crate::util::error::{PgprError, Result};
use crate::util::json::Json;

/// `pgpr experiment <id> [--full] [--backend sim|threads[:N]]`.
///
/// `backend` selects the execution backend for experiments with parallel
/// runs (currently Table 2); the others are backend-independent.
pub fn cmd_experiment(id: &str, full: bool, backend: BackendKind) -> Result<()> {
    match id {
        "table1a" => {
            let p = if full {
                table1::Table1Params::full_for(Workload::Sarcos)
            } else {
                table1::Table1Params::default_for(Workload::Sarcos)
            };
            table1::run(&p)?;
        }
        "table1b" => {
            let p = if full {
                table1::Table1Params::full_for(Workload::Aimpeak)
            } else {
                table1::Table1Params::default_for(Workload::Aimpeak)
            };
            table1::run(&p)?;
        }
        "table2" => {
            let mut p =
                if full { table2::Table2Params::full() } else { table2::Table2Params::default() };
            p.backend = backend;
            table2::run(&p)?;
        }
        "table3" => {
            let p = if full {
                table3::Table3Params::full()
            } else {
                table3::Table3Params::default()
            };
            table3::run(&p)?;
        }
        "fig2" => {
            let p = if full { fig2::Fig2Params::full() } else { fig2::Fig2Params::default() };
            fig2::run(&p)?;
        }
        "fig6" => {
            fig6::run(42)?;
        }
        "ablation" => {
            ablation::run(42)?;
        }
        "all" => {
            for id in ["table1a", "table1b", "table2", "table3", "fig2", "fig6", "ablation"] {
                cmd_experiment(id, full, backend)?;
            }
        }
        other => {
            return Err(PgprError::Config(format!(
                "unknown experiment `{other}` (try table1a, table1b, table2, table3, fig2, fig6, ablation, all)"
            )))
        }
    }
    Ok(())
}

/// `pgpr data gen` — write train/test CSVs.
pub fn cmd_data_gen(dataset: &str, train: usize, test: usize, seed: u64, out: &str) -> Result<()> {
    let w = Workload::parse(dataset)?;
    let ds = w.generate(train, test, seed)?;
    ds.validate()?;
    for (tag, x, y) in [
        ("train", &ds.train_x, &ds.train_y),
        ("test", &ds.test_x, &ds.test_y),
    ] {
        let mut header: Vec<String> = (0..ds.dim()).map(|j| format!("x{j}")).collect();
        header.push("y".into());
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = CsvTable::new(&header_refs);
        for i in 0..x.rows() {
            let mut row: Vec<f64> = x.row(i).to_vec();
            row.push(y[i]);
            t.push_nums(&row);
        }
        let path = format!("{out}/{}_{tag}.csv", ds.name);
        t.write_path(&path)?;
        println!("wrote {path} ({} rows)", x.rows());
    }
    Ok(())
}

/// Load a dataset CSV written by `cmd_data_gen`.
pub fn load_xy_csv(path: &str) -> Result<(crate::linalg::matrix::Mat, Vec<f64>)> {
    let t = CsvTable::read_path(path)?;
    let d = t.header.len() - 1;
    if t.header.last().map(|s| s.as_str()) != Some("y") {
        return Err(PgprError::Data(format!("{path}: last column must be `y`")));
    }
    let n = t.rows.len();
    let mut x = crate::linalg::matrix::Mat::zeros(n, d);
    let mut y = vec![0.0; n];
    for (i, row) in t.rows.iter().enumerate() {
        for j in 0..d {
            let v = row[j]
                .parse()
                .map_err(|_| PgprError::Data(format!("bad cell {}", row[j])))?;
            x.set(i, j, v);
        }
        y[i] = row[d].parse().map_err(|_| PgprError::Data(format!("bad cell {}", row[d])))?;
    }
    Ok((x, y))
}

/// `pgpr eval` — fit LMA on a training CSV, evaluate on a test CSV,
/// write per-point predictions and print metrics.
pub fn cmd_eval(
    train_csv: &str,
    test_csv: &str,
    m: usize,
    b: usize,
    s: usize,
    seed: u64,
    out: &str,
) -> Result<()> {
    let (train_x, train_y) = load_xy_csv(train_csv)?;
    let (test_x, test_y) = load_xy_csv(test_csv)?;
    let ds = crate::data::Dataset {
        name: "csv".into(),
        train_x,
        train_y,
        test_x,
        test_y,
    };
    ds.validate()?;
    let hyp = crate::experiments::common::learn_hypers(&ds, 512.min(ds.train_x.rows()), seed)?;
    let cfg = LmaConfig {
        num_blocks: m,
        markov_order: b,
        support_size: s,
        seed,
        partition: PartitionStrategy::KMeans { iters: 10 },
        use_pjrt: false,
    };
    let (model, fit_secs) =
        crate::util::timer::time_it(|| LmaRegressor::fit(&ds.train_x, &ds.train_y, &hyp, &cfg));
    let model = model?;
    let (pred, pred_secs) = crate::util::timer::time_it(|| model.predict(&ds.test_x));
    let pred = pred?;
    let rmse = crate::metrics::rmse(&pred.mean, &ds.test_y);
    let mnlp = crate::metrics::mnlp(&pred.mean, &pred.var, &ds.test_y);
    println!(
        "LMA(M={m}, B={b}, |S|={s}): rmse {rmse:.6}  mnlp {mnlp:.4}  fit {fit_secs:.2}s  predict {pred_secs:.2}s"
    );
    let mut t = CsvTable::new(&["y_true", "mean", "var"]);
    for i in 0..pred.mean.len() {
        t.push_nums(&[ds.test_y[i], pred.mean[i], pred.var[i]]);
    }
    t.write_path(out)?;
    println!("wrote {out}");
    Ok(())
}

/// `pgpr eval --artifact name=path` — warm-start evaluation: load a saved
/// snapshot and score it on a test CSV without refitting anything. Prints
/// today's RMSE/MNLP next to the artifact's stored fit-time baseline
/// (when present), so offline drift checks use the same reference the
/// serving drift detector does.
pub fn cmd_eval_artifact(spec: &str, test_csv: &str, out: &str) -> Result<()> {
    let (name, path) = parse_model_spec(spec)?;
    let (test_x, test_y) = load_xy_csv(test_csv)?;
    let engine = artifact::load_engine(&path)?;
    let dim = engine.core().hyp.dim();
    if test_x.cols() != dim {
        return Err(PgprError::Data(format!(
            "{test_csv}: {} input columns but artifact `{name}` expects {dim}",
            test_x.cols()
        )));
    }
    let (pred, pred_secs) = crate::util::timer::time_it(|| engine.predict(&test_x));
    let pred = pred?;
    let rmse = crate::metrics::rmse(&pred.mean, &test_y);
    let mnlp = crate::metrics::mnlp(&pred.mean, &pred.var, &test_y);
    let core = engine.core();
    println!(
        "artifact {name} ({path}; |D|={}, M={}, B={}, |S|={}): rmse {rmse:.6}  mnlp {mnlp:.4}  predict {pred_secs:.2}s",
        core.part.total(),
        core.m(),
        core.b(),
        core.basis.size(),
    );
    match core.quality_baseline {
        Some(b) => println!(
            "fit-time baseline ({} held-out rows): rmse {:.6}  mnlp {:.4}  drift (mnlp − baseline) {:+.4}",
            b.rows, b.rmse, b.mnlp, mnlp - b.mnlp
        ),
        None => println!("fit-time baseline: none recorded (pre-quality artifact)"),
    }
    let mut t = CsvTable::new(&["y_true", "mean", "var"]);
    for i in 0..pred.mean.len() {
        t.push_nums(&[test_y[i], pred.mean[i], pred.var[i]]);
    }
    t.write_path(out)?;
    println!("wrote {out}");
    Ok(())
}

/// `pgpr serve` parameters: which model(s) to front and how.
#[derive(Clone, Debug)]
pub struct ServeCmd {
    pub dataset: String,
    pub train: usize,
    pub seed: u64,
    /// `centralized` | `sim` | `threads[:N]`.
    pub backend: String,
    /// HTTP/batching options; an empty `opts.listen` selects the stdin
    /// line protocol instead of HTTP.
    pub opts: ServeOptions,
    /// `name=path` artifact specs (repeatable `--model`). Non-empty ⇒
    /// boot from saved artifacts **without touching training data**; the
    /// first listed model is the default.
    pub models: Vec<String>,
    /// Registry capacity for `PUT /models/<name>` loads at runtime.
    pub max_models: usize,
    /// Observed rows buffered per model before an incremental update
    /// publishes a new generation (`POST /models/<name>/observe`).
    pub observe_flush_rows: usize,
    /// Rewrite a model's artifact in place after each published update
    /// (models loaded from snapshots only; untouched blocks reuse their
    /// previous encodings).
    pub resnapshot: bool,
    /// Prequential scoring selector for observed rows:
    /// `off` | `sample:K` | `all` (`RegistryOptions::observe_score`).
    pub observe_score: String,
    /// Sliding quality window capacity in scored rows.
    pub quality_window: usize,
    /// Windowed-MNLP-minus-baseline threshold that fires `drift_detected`.
    pub drift_threshold: f64,
    /// Observation rows buffered per model before `POST …/observe`
    /// returns 429 backpressure instead of growing without bound.
    pub observe_max_rows: usize,
}

impl ServeCmd {
    fn registry_options(&self, min_models: usize) -> Result<RegistryOptions> {
        Ok(RegistryOptions {
            max_models: self.max_models.max(min_models).max(1),
            lru_evict: true,
            observe_flush_rows: self.observe_flush_rows.max(1),
            resnapshot: self.resnapshot,
            observe_score: ScoreMode::parse(&self.observe_score)?,
            quality_window: self.quality_window,
            drift_threshold: self.drift_threshold,
            observe_max_rows: self.observe_max_rows.max(1),
        })
    }
}

/// Fit a serving engine: synthetic workload, quick hypers. `blocks`,
/// `order` and `support` of 0 mean "auto-scale to |D|" (the historical
/// `pgpr serve` behavior: M = |D|/128, B = 1, |S| = |D|/16).
fn build_serve_engine(
    dataset: &str,
    train: usize,
    seed: u64,
    backend: &str,
    blocks: usize,
    order: usize,
    support: usize,
) -> Result<(ServeEngine, String)> {
    let w = Workload::parse(dataset)?;
    let ds = w.generate(train, train / 4, seed)?;
    let hyp = crate::experiments::common::quick_hypers(&ds);
    let m = if blocks == 0 { (train / 128).clamp(2, 32) } else { blocks };
    let b = if order == 0 { 1.min(m - 1) } else { order.min(m - 1) };
    let s = if support == 0 { (train / 16).clamp(8, 512) } else { support };
    let cfg = LmaConfig {
        num_blocks: m,
        markov_order: b,
        support_size: s,
        seed,
        partition: PartitionStrategy::KMeans { iters: 8 },
        use_pjrt: false,
    };
    let mut engine = if backend == "centralized" {
        ServeEngine::Centralized(LmaRegressor::fit(&ds.train_x, &ds.train_y, &hyp, &cfg)?)
    } else {
        let kind = BackendKind::parse(backend)?;
        let cc = ClusterConfig::gigabit(1, m).with_backend(kind);
        ServeEngine::Parallel(ParallelLma::fit(&ds.train_x, &ds.train_y, &hyp, &cfg, &cc)?)
    };
    // Fit-time quality baseline: score the held-out split once and stamp
    // RMSE/MNLP into the core, where artifact serialization persists it —
    // the reference the serving drift detector measures windowed NLPD
    // against (`pgpr_model_drift_score`).
    if !ds.test_y.is_empty() {
        let pred = engine.predict(&ds.test_x)?;
        engine.set_quality_baseline(QualityBaseline {
            rmse: crate::metrics::rmse(&pred.mean, &ds.test_y),
            mnlp: crate::metrics::mnlp(&pred.mean, &pred.var, &ds.test_y),
            rows: ds.test_y.len(),
        });
    }
    Ok((engine, ds.name))
}

/// Parse a `--model name=path` / `--artifact name=path` spec.
fn parse_model_spec(s: &str) -> Result<(String, String)> {
    match s.split_once('=') {
        Some((name, path)) if !name.trim().is_empty() && !path.trim().is_empty() => {
            Ok((name.trim().to_string(), path.trim().to_string()))
        }
        _ => Err(PgprError::Config(format!("expected name=path, got `{s}`"))),
    }
}

/// Parse an extended `--model name=path[,slo=MS][,weight=W]` spec: the
/// per-model admission SLO and QoS weight ride along after the path,
/// comma-separated. Absent options fall back to the server-wide
/// `--slo-ms` and weight 1.
fn parse_model_spec_policy(
    s: &str,
    default_slo_ms: u64,
) -> Result<(String, String, AdmissionPolicy)> {
    let mut parts = s.split(',');
    let (name, path) = parse_model_spec(parts.next().unwrap_or(""))?;
    let mut slo_ms = default_slo_ms;
    let mut weight = 1u64;
    for opt in parts {
        let opt = opt.trim();
        if opt.is_empty() {
            continue;
        }
        match opt.split_once('=') {
            Some((k, v)) if k.trim() == "slo" => {
                slo_ms = v.trim().parse().map_err(|_| {
                    PgprError::Config(format!("bad slo `{v}` in model spec `{s}`"))
                })?;
            }
            Some((k, v)) if k.trim() == "weight" => {
                weight = v.trim().parse().map_err(|_| {
                    PgprError::Config(format!("bad weight `{v}` in model spec `{s}`"))
                })?;
            }
            _ => {
                return Err(PgprError::Config(format!(
                    "unknown model-spec option `{opt}` in `{s}` (expected slo=MS or weight=W)"
                )))
            }
        }
    }
    Ok((name, path, AdmissionPolicy::from_millis(slo_ms, weight)))
}

/// Load `name=path` artifact specs into a fresh registry (the shared
/// boot path of `pgpr serve --model` and self-contained
/// `pgpr loadtest --artifact`). The first spec becomes the default
/// model; capacity is at least the number of specs. Each model remembers
/// its snapshot path, so `--resnapshot` can rewrite it after online
/// updates.
fn registry_from_artifacts(
    specs: &[String],
    opts: &ServeOptions,
    reg_opts: RegistryOptions,
    context: &str,
) -> Result<Arc<ModelRegistry>> {
    let specs: Vec<(String, String, AdmissionPolicy)> = specs
        .iter()
        .map(|s| parse_model_spec_policy(s, opts.slo_ms))
        .collect::<Result<_>>()?;
    let reg_opts = RegistryOptions {
        max_models: reg_opts.max_models.max(specs.len()).max(1),
        ..reg_opts
    };
    let registry = Arc::new(ModelRegistry::new(reg_opts, opts));
    for (name, path, policy) in &specs {
        let engine = artifact::load_engine(path)?;
        registry
            .load_with_policy(name, Arc::new(engine), path, *policy)
            .map_err(|e| PgprError::Config(e.to_string()))?;
        log_event(
            Level::Info,
            "artifact_loaded",
            vec![
                ("model", Json::Str(name.clone())),
                ("path", Json::Str(path.clone())),
                ("context", Json::Str(context.to_string())),
                (
                    "slo_ms",
                    Json::Num(policy.slo.map(|d| d.as_millis() as f64).unwrap_or(0.0)),
                ),
                ("weight", Json::Num(policy.weight as f64)),
            ],
        );
    }
    Ok(registry)
}

/// `pgpr fit` parameters: fit once, snapshot the engine to disk.
#[derive(Clone, Debug)]
pub struct FitCmd {
    pub dataset: String,
    pub train: usize,
    pub seed: u64,
    pub backend: String,
    /// 0 = auto (M = |D|/128 clamped to [2, 32]).
    pub blocks: usize,
    /// Markov order B (clamped to M−1).
    pub order: usize,
    /// 0 = auto (|S| = |D|/16 clamped to [8, 512]).
    pub support: usize,
    /// Artifact output path.
    pub save: String,
    /// Print the fit-phase profiler breakdown after fitting.
    pub profile: bool,
}

/// `pgpr fit` — fit a serving engine and save it as a model artifact
/// (`registry::artifact` format) for later `pgpr serve --model`.
pub fn cmd_fit(c: &FitCmd) -> Result<()> {
    let (engine, name) = build_serve_engine(
        &c.dataset,
        c.train,
        c.seed,
        &c.backend,
        c.blocks,
        c.order,
        c.support,
    )?;
    let core = engine.core();
    artifact::save_engine(&engine, &c.save)?;
    let bytes = std::fs::metadata(&c.save).map(|m| m.len()).unwrap_or(0);
    println!(
        "fitted {name} (|D|={}, M={}, B={}, |S|={}, backend {}) -> {} ({bytes} bytes)",
        core.part.total(),
        core.m(),
        core.b(),
        core.basis.size(),
        engine.backend_name(),
        c.save
    );
    if let Some(b) = core.quality_baseline {
        println!(
            "  held-out baseline: rmse {:.4}, mnlp {:.4} ({} rows) — drift reference",
            b.rmse, b.mnlp, b.rows
        );
    }
    if c.profile {
        // Same phase taxonomy the registry exports via `/models/{name}`
        // (`fit_phases_s`), so offline and serving views agree.
        match engine.fit_profiler() {
            Some(prof) => print!("{}", prof.report()),
            None => println!("  (no fit profile recorded for backend {})", engine.backend_name()),
        }
    }
    Ok(())
}

/// `pgpr serve` — HTTP mode (`--listen host:port`): boots the
/// `server::http` stack (acceptor, keep-alive worker pool, per-model
/// micro-batchers) and runs until stdin closes or a `quit` line arrives,
/// then prints the metrics summary. With repeatable `--model name=path`
/// the engines are loaded from saved artifacts — no training data is
/// read or fitted — and all models are served from one registry. Stdin
/// mode (`--listen ""`, the default): the legacy line protocol
/// `predict v1,v2,...` → `id mean var`, with `flush` forcing a partial
/// batch and EOF flushing and printing stats.
pub fn cmd_serve(c: &ServeCmd) -> Result<()> {
    if !c.models.is_empty() {
        if c.opts.listen.is_empty() {
            // Admission policies are parsed (and validated) but inert in
            // stdin mode: there is no queue to gate.
            let specs: Vec<(String, String, AdmissionPolicy)> = c
                .models
                .iter()
                .map(|s| parse_model_spec_policy(s, c.opts.slo_ms))
                .collect::<Result<_>>()?;
            if specs.len() > 1 {
                return Err(PgprError::Config(
                    "stdin mode serves a single model; use --listen for the multi-model registry"
                        .into(),
                ));
            }
            let (name, path, _policy) = &specs[0];
            let engine = artifact::load_engine(path)?;
            log_event(
                Level::Info,
                "artifact_loaded",
                vec![
                    ("model", Json::Str(name.clone())),
                    ("path", Json::Str(path.clone())),
                    ("context", Json::Str("serve-stdin".into())),
                ],
            );
            return serve_stdin(c, engine, name);
        }
        let registry =
            registry_from_artifacts(&c.models, &c.opts, c.registry_options(0)?, "serve")?;
        let server = Server::start_with_registry(registry, &c.opts)?;
        return serve_http_run(c, server, "artifacts");
    }
    let (engine, name) =
        build_serve_engine(&c.dataset, c.train, c.seed, &c.backend, 0, 1, 0)?;
    if !c.opts.listen.is_empty() {
        return serve_http(c, engine, &name);
    }
    serve_stdin(c, engine, &name)
}

/// Stdin line-protocol serving over one engine.
fn serve_stdin(c: &ServeCmd, engine: ServeEngine, name: &str) -> Result<()> {
    // Same semantics as the HTTP batcher: 0 = no batching delay (the
    // deadline is always already expired, so partial batches flush at
    // the first opportunity).
    let backend = engine.backend_name();
    let mode = if c.opts.f32_u {
        if matches!(engine, ServeEngine::Parallel(_)) {
            log_event(
                Level::Info,
                "f32u_fallback",
                vec![(
                    "reason",
                    Json::Str("cluster backends have no f32 context; serving exact f64".into()),
                )],
            );
        }
        PredictMode::F32U
    } else {
        PredictMode::F64
    };
    let mut svc = PredictionService::with_engine(engine, c.opts.batch_size)?
        .with_max_delay(Duration::from_micros(c.opts.max_delay_us))
        .with_predict_mode(mode);
    log_event(
        Level::Info,
        "serving",
        vec![
            ("model", Json::Str(name.to_string())),
            ("protocol", Json::Str("stdin".into())),
            ("dim", Json::Num(svc.dim() as f64)),
            ("batch", Json::Num(c.opts.batch_size as f64)),
            ("backend", Json::Str(backend.to_string())),
        ],
    );
    eprintln!("protocol: `predict v1,v2,...` | `flush` | EOF");
    let stdin = std::io::stdin();
    let mut next_id = 0u64;
    for line in stdin.lock().lines() {
        // Answer anything whose max_delay deadline lapsed while we
        // waited for input. Stdin blocks with no timeout, so this only
        // runs when the next line arrives — the hard deadline guarantee
        // is the HTTP batcher's (it waits with recv_timeout); here it
        // just keeps an interactive session from replaying stale rows.
        for r in svc.flush_expired()? {
            println!("{} {:.6} {:.6}", r.id, r.mean, r.var);
        }
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "flush" {
            for r in svc.flush()? {
                println!("{} {:.6} {:.6}", r.id, r.mean, r.var);
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("predict ") {
            let x: std::result::Result<Vec<f64>, _> =
                rest.split(',').map(|s| s.trim().parse::<f64>()).collect();
            let x = x.map_err(|_| PgprError::Data(format!("bad request `{line}`")))?;
            next_id += 1;
            for r in svc.submit(Request { id: next_id, x })? {
                println!("{} {:.6} {:.6}", r.id, r.mean, r.var);
            }
        } else {
            log_event(Level::Info, "unknown_command", vec![("line", Json::Str(line.to_string()))]);
        }
    }
    for r in svc.flush()? {
        println!("{} {:.6} {:.6}", r.id, r.mean, r.var);
    }
    let (p50, p95, p99) = svc.latency_quantiles();
    eprintln!(
        "served {} requests in {} batches; latency mean {:.4}s p50 {:.4}s p95 {:.4}s p99 {:.4}s; throughput {:.1} req/s",
        svc.served,
        svc.batches,
        svc.mean_latency(),
        p50,
        p95,
        p99,
        svc.throughput()
    );
    Ok(())
}

fn serve_http(c: &ServeCmd, engine: ServeEngine, name: &str) -> Result<()> {
    // Build the registry here (rather than Server::start) so the
    // `--max-models` cap (and the observe options) apply to runtime
    // `PUT /models` loads too.
    let registry = Arc::new(ModelRegistry::new(c.registry_options(0)?, &c.opts));
    registry
        .load(crate::server::http::DEFAULT_MODEL, Arc::new(engine))
        .map_err(|e| PgprError::Config(e.to_string()))?;
    let server = Server::start_with_registry(registry, &c.opts)?;
    serve_http_run(c, server, name)
}

/// Shared HTTP serving loop: print the bound address, run until `quit`
/// or stdin EOF, shut down with a metrics summary.
fn serve_http_run(c: &ServeCmd, server: Server, name: &str) -> Result<()> {
    let addr = server.addr();
    let models: Vec<String> =
        server.registry().list().into_iter().map(|i| i.name).collect();
    log_event(
        Level::Info,
        "serving",
        vec![
            ("model", Json::Str(name.to_string())),
            ("protocol", Json::Str("http".into())),
            ("addr", Json::Str(addr.to_string())),
            ("models", Json::Arr(models.iter().map(|m| Json::Str(m.clone())).collect())),
            ("workers", Json::Num(c.opts.workers as f64)),
            ("batch", Json::Num(c.opts.batch_size as f64)),
            ("max_delay_us", Json::Num(c.opts.max_delay_us as f64)),
            ("queue", Json::Num(c.opts.queue_capacity as f64)),
            ("keep_alive", Json::Bool(c.opts.keep_alive)),
            ("trace", Json::Bool(c.opts.trace)),
        ],
    );
    eprintln!(
        "endpoints: POST /predict[?trace=1]  GET/PUT/DELETE /models[/name]  GET /healthz  \
         GET /readyz  GET /metrics[?format=json]  GET /debug/trace  GET /debug/quality  \
         GET /debug/prof — `quit` on stdin stops"
    );
    // Machine-readable bound address on stdout so scripts can pick up
    // the ephemeral port from `--listen 127.0.0.1:0`.
    println!("listening {addr}");
    let stdin = std::io::stdin();
    let mut quit = false;
    for line in stdin.lock().lines() {
        let line = line?;
        if line.trim() == "quit" {
            quit = true;
            break;
        }
    }
    if !quit {
        // Stdin closed (detached/daemonized run, `… </dev/null &`):
        // keep serving until the process is killed.
        log_event(Level::Info, "stdin_closed", vec![("detached", Json::Bool(true))]);
        loop {
            std::thread::park();
        }
    }
    let metrics = server.shutdown();
    eprintln!("{}", metrics.summary());
    Ok(())
}

/// `pgpr loadtest` parameters.
#[derive(Clone, Debug)]
pub struct LoadtestCmd {
    /// Target `host:port`; empty = boot an in-process server first.
    pub addr: String,
    /// Self-mode model parameters (ignored when `addr` is set).
    pub dataset: String,
    pub train: usize,
    pub seed: u64,
    pub backend: String,
    pub opts: ServeOptions,
    /// Load shape.
    pub concurrency: usize,
    pub requests: usize,
    pub rows: usize,
    /// Open-loop arrival rate (req/s) for the additional
    /// coordinated-omission-corrected pass; 0 = closed-loop only.
    pub rate: f64,
    /// Output path of the machine-readable record.
    pub out: String,
    /// Connection mode(s): `keepalive`, `close` or `both`.
    pub mode: String,
    /// Named registry models the traffic round-robins across. In
    /// self-contained mode these are also fitted and registered: each
    /// name gets its own (|S|, B) operating point along the LMA spectrum.
    pub models: Vec<String>,
    /// Self-mode `name=path` artifact specs: serve these saved models
    /// instead of fitting (the artifact round-trip smoke path).
    pub artifacts: Vec<String>,
}

impl Default for LoadtestCmd {
    fn default() -> Self {
        LoadtestCmd {
            addr: String::new(),
            dataset: "aimpeak".into(),
            train: 600,
            seed: 0,
            backend: "threads:0".into(),
            opts: ServeOptions { listen: "127.0.0.1:0".into(), ..ServeOptions::default() },
            concurrency: 8,
            requests: 200,
            rows: 1,
            rate: 0.0,
            out: "BENCH_serve_latency.json".into(),
            mode: "both".into(),
            models: Vec::new(),
            artifacts: Vec::new(),
        }
    }
}

/// Boot the self-contained server for `run_loadtest`: from saved
/// artifacts when given, else fit — one engine per requested model name
/// (stepping the (|S|, B) operating point per variant), or the single
/// anonymous default engine.
fn boot_self_server(c: &LoadtestCmd) -> Result<Server> {
    let mut opts = c.opts.clone();
    if opts.listen.is_empty() {
        opts.listen = "127.0.0.1:0".into();
    }
    // Keep-alive pins one persistent connection to one worker for the
    // whole run, so fewer workers than closed-loop clients would leave
    // the excess clients unserved until a worker frees — their
    // run-length waits would poison the recorded latency quantiles.
    if opts.keep_alive {
        opts.workers = opts.workers.max(c.concurrency);
    }
    if !c.artifacts.is_empty() {
        let registry =
            registry_from_artifacts(&c.artifacts, &opts, RegistryOptions::default(), "loadtest")?;
        return Server::start_with_registry(registry, &opts);
    }
    if !c.models.is_empty() {
        let registry = Arc::new(ModelRegistry::new(
            RegistryOptions { max_models: c.models.len().max(8), ..Default::default() },
            &opts,
        ));
        for (i, name) in c.models.iter().enumerate() {
            // Walk the LMA spectrum across variants: halve the support
            // set and raise the Markov order with each successive model.
            let support = ((c.train / 16) >> i).clamp(8, 512);
            let (engine, _) = build_serve_engine(
                &c.dataset,
                c.train,
                c.seed,
                &c.backend,
                0,
                1 + i,
                support,
            )?;
            registry
                .load(name, Arc::new(engine))
                .map_err(|e| PgprError::Config(e.to_string()))?;
            log_event(
                Level::Info,
                "model_fitted",
                vec![
                    ("model", Json::Str(name.clone())),
                    ("support", Json::Num(support as f64)),
                    ("order_base", Json::Num((1 + i) as f64)),
                    ("context", Json::Str("loadtest".into())),
                ],
            );
        }
        return Server::start_with_registry(registry, &opts);
    }
    let (engine, _name) =
        build_serve_engine(&c.dataset, c.train, c.seed, &c.backend, 0, 1, 0)?;
    Server::start(engine, &opts)
}

/// Run the load test and return the `BENCH_serve_latency` record (also
/// used by `bench_serve_latency`). Self-contained mode boots the HTTP
/// stack on an ephemeral port (fitting engines, or loading `--artifact`
/// snapshots), drives it in the requested connection mode(s) and shuts
/// it down, embedding both client- and server-side quantiles.
pub fn run_loadtest(c: &LoadtestCmd) -> Result<Json> {
    let ka_modes: Vec<bool> = match c.mode.as_str() {
        "both" => vec![true, false],
        "keepalive" | "keep-alive" => vec![true],
        "close" => vec![false],
        other => {
            return Err(PgprError::Config(format!(
                "unknown loadtest mode `{other}` (expected keepalive | close | both)"
            )))
        }
    };
    let (addr, server) = if c.addr.is_empty() {
        let server = boot_self_server(c)?;
        (server.addr().to_string(), Some(server))
    } else {
        (c.addr.clone(), None)
    };
    // With named model targets the loadgen resolves each model's dim
    // from `GET /models/<name>` itself; the default-model dim is only
    // needed for anonymous traffic.
    let dim = if c.models.is_empty() { loadgen::fetch_dim(&addr)? } else { 0 };
    let mut reports = Vec::with_capacity(ka_modes.len());
    for keep_alive in ka_modes {
        let lc = loadgen::LoadConfig {
            addr: addr.clone(),
            concurrency: c.concurrency,
            requests: c.requests,
            rows_per_request: c.rows,
            dim,
            seed: c.seed,
            keep_alive,
            models: c.models.clone(),
            rate_rps: 0.0,
        };
        let report = loadgen::run(&lc)?;
        eprintln!("{}", report.render());
        reports.push(report);
    }
    // Optional open-loop pass: fixed arrival rate over keep-alive
    // connections, latency measured from the scheduled arrival
    // (coordinated-omission corrected) — reported alongside the
    // closed-loop records.
    let open_report = if c.rate > 0.0 {
        let lc = loadgen::LoadConfig {
            addr: addr.clone(),
            concurrency: c.concurrency,
            requests: c.requests,
            rows_per_request: c.rows,
            dim,
            seed: c.seed,
            keep_alive: true,
            models: c.models.clone(),
            rate_rps: c.rate,
        };
        let report = loadgen::run(&lc)?;
        eprintln!("{}", report.render());
        Some(report)
    } else {
        None
    };
    let mode = if server.is_some() { "self" } else { "remote" };
    let headline = &reports[0];
    let mut fields: Vec<(&str, Json)> = vec![
        ("bench", Json::Str("serve_latency".into())),
        ("mode", Json::Str(mode.to_string())),
        ("addr", Json::Str(addr)),
        ("concurrency", Json::Num(c.concurrency as f64)),
        ("requests", Json::Num(c.requests as f64)),
        ("rows_per_request", Json::Num(c.rows as f64)),
        // Headline numbers duplicated at top level for easy extraction
        // (the first requested connection mode — keep-alive for `both`).
        ("throughput_rps", Json::Num(headline.throughput_rps)),
        ("p50_s", Json::Num(headline.p50_s)),
        ("p95_s", Json::Num(headline.p95_s)),
        ("p99_s", Json::Num(headline.p99_s)),
        ("client", headline.to_json()),
    ];
    if !c.models.is_empty() {
        let names: Vec<Json> = c.models.iter().map(|m| Json::Str(m.clone())).collect();
        fields.push(("models", Json::Arr(names)));
    }
    for r in &reports {
        // One entry per connection mode so the record tracks the
        // keep-alive vs per-request-TCP gap across PRs.
        fields.push(if r.keep_alive {
            ("client_keepalive", r.to_json())
        } else {
            ("client_close", r.to_json())
        });
    }
    if let Some(r) = &open_report {
        fields.push(("rate_rps", Json::Num(c.rate)));
        // Overload headline numbers: how much the admission gate shed
        // and what actually got through (successful rows per second).
        fields.push(("open_shed_rate", Json::Num(r.shed_rate())));
        fields.push(("open_goodput_rows_per_s", Json::Num(r.goodput_rows_per_s)));
        fields.push(("client_open", r.to_json()));
    }
    if let Some(server) = server {
        // Engine/batcher configuration is only known (and only true) in
        // self-contained mode; a remote server's settings are its own.
        fields.push(("backend", Json::Str(c.backend.clone())));
        fields.push(("dataset", Json::Str(c.dataset.clone())));
        fields.push(("train", Json::Num(c.train as f64)));
        fields.push(("batch_size", Json::Num(c.opts.batch_size as f64)));
        fields.push(("max_delay_us", Json::Num(c.opts.max_delay_us as f64)));
        fields.push(("trace", Json::Bool(c.opts.trace)));
        // Per-model server-side histograms (each model batches its own
        // traffic), so multi-model runs aren't summarized by just the
        // default model's numbers.
        let per_model: std::collections::BTreeMap<String, Json> = server
            .registry()
            .metrics_by_model()
            .into_iter()
            .map(|(n, m)| (n, m.to_json()))
            .collect();
        if per_model.len() > 1 {
            fields.push(("server_models", Json::Obj(per_model)));
        }
        // NB: `server` is the default model's metrics and spans every
        // connection mode driven above; per-mode client numbers live in
        // `client_keepalive` / `client_close`.
        let metrics = server.shutdown();
        eprintln!("{}", metrics.summary());
        fields.push(("server", metrics.to_json()));
    }
    Ok(Json::obj(fields))
}

/// `pgpr loadtest` — drive a serving stack and write
/// `BENCH_serve_latency.json`.
pub fn cmd_loadtest(c: &LoadtestCmd) -> Result<()> {
    let record = run_loadtest(c)?;
    crate::util::bench::write_json_record(&c.out, &record)?;
    println!("wrote {}", c.out);
    Ok(())
}

/// `pgpr observe` parameters: replay a CSV observation stream into a
/// served model.
#[derive(Clone, Debug)]
pub struct ObserveCmd {
    /// Target `host:port` of a running `pgpr serve --listen`.
    pub addr: String,
    /// Registry model name to stream into.
    pub model: String,
    /// Observation CSV (same `x0..xd-1, y` schema as `pgpr eval` inputs).
    pub csv: String,
    /// Rows per observe request.
    pub batch_rows: usize,
    /// Buffer intermediate batches server-side and publish one update at
    /// the end (the last request flushes).
    pub buffer: bool,
    /// Replay at most this many rows (0 = the whole file).
    pub limit: usize,
}

/// `pgpr observe` — offline replay of an observation stream into a live
/// model over `POST /models/<name>/observe` (one keep-alive connection).
pub fn cmd_observe(c: &ObserveCmd) -> Result<()> {
    if c.addr.is_empty() {
        return Err(PgprError::Config("observe: --addr host:port is required".into()));
    }
    if c.batch_rows == 0 {
        return Err(PgprError::Config("observe: --batch-rows must be ≥ 1".into()));
    }
    let (x, y) = load_xy_csv(&c.csv)?;
    let total = if c.limit == 0 { x.rows() } else { c.limit.min(x.rows()) };
    if total == 0 {
        return Err(PgprError::Data(format!("{}: no observation rows", c.csv)));
    }
    let mut conn = loadgen::HttpConn::connect(&c.addr)?;
    let path = format!("/models/{}/observe", c.model);
    let t0 = std::time::Instant::now();
    let mut sent = 0usize;
    let mut batches = 0usize;
    let mut last = Json::Null;
    while sent < total {
        let take = c.batch_rows.min(total - sent);
        let rows: Vec<Json> = (sent..sent + take).map(|i| Json::arr_f64(x.row(i))).collect();
        let mut fields: Vec<(&str, Json)> = vec![
            ("rows", Json::Arr(rows)),
            ("y", Json::arr_f64(&y[sent..sent + take])),
        ];
        // Intermediate batches only buffer when requested; the final
        // batch always publishes whatever is pending (even when the
        // server's flush threshold is larger than the batch).
        if c.buffer && sent + take < total {
            fields.push(("buffer", Json::Bool(true)));
        } else if sent + take >= total {
            fields.push(("flush", Json::Bool(true)));
        }
        let body = Json::obj(fields).to_string();
        let (status, resp, closes) = conn.request("POST", &path, Some(&body))?;
        if status != 200 {
            return Err(PgprError::Data(format!(
                "observe batch at row {sent} returned {status}: {resp}"
            )));
        }
        // The server closes a connection after max-conn-requests;
        // re-establish so replays longer than that cap keep going.
        if closes {
            conn = loadgen::HttpConn::connect(&c.addr)?;
        }
        last = Json::parse(&resp)?;
        sent += take;
        batches += 1;
    }
    let secs = t0.elapsed().as_secs_f64();
    let gen = last.get("generation").and_then(|v| v.as_usize()).unwrap_or(0);
    let blocks = last.get("blocks").and_then(|v| v.as_usize()).unwrap_or(0);
    let train_rows = last.get("train_rows").and_then(|v| v.as_usize()).unwrap_or(0);
    println!(
        "observed {sent} rows in {batches} batches over {secs:.2}s ({:.1} rows/s); \
         model `{}` now at generation {gen} ({blocks} blocks, {train_rows} rows)",
        sent as f64 / secs.max(1e-9),
        c.model,
    );
    Ok(())
}

/// `pgpr bench-info`: report artifact availability.
pub fn cmd_bench_info() -> Result<()> {
    match crate::runtime::artifacts::ArtifactLibrary::try_default() {
        Some(lib) => {
            println!("artifacts: loaded {} entries", lib.entries().len());
            for e in lib.entries() {
                println!("  {} {}x{} d={} ({})", e.name, e.n1, e.n2, e.d, e.file);
            }
        }
        None => println!("artifacts: not built (run `make artifacts`); native path active"),
    }
    Ok(())
}

/// `pgpr top` parameters: poll a live server's resource profile.
#[derive(Clone, Debug)]
pub struct TopCmd {
    /// Target `host:port` of a running `pgpr serve --listen`.
    pub addr: String,
    /// Poll cadence in milliseconds.
    pub interval_ms: u64,
    /// Number of polls; 0 = until interrupted.
    pub iters: usize,
}

/// `pgpr top` — poll `GET /metrics?format=json` on a running server and
/// print a process/thread resource table: RSS, live/peak heap, fd and
/// connection counts, and per-thread CPU. Utilization needs two samples
/// (it is the CPU delta over the wall-clock delta), so the first frame
/// prints cumulative seconds only.
pub fn cmd_top(c: &TopCmd) -> Result<()> {
    if c.addr.is_empty() {
        return Err(PgprError::Config("top: --addr host:port is required".into()));
    }
    // Previous frame: (poll instant, per-thread cpu seconds, process cpu).
    let mut prev: Option<(std::time::Instant, std::collections::BTreeMap<String, f64>, f64)> =
        None;
    let mut iter = 0usize;
    loop {
        let (status, body) =
            loadgen::http_request(&c.addr, "GET", "/metrics?format=json", None)?;
        if status != 200 {
            return Err(PgprError::Data(format!("GET /metrics returned {status}: {body}")));
        }
        let now = std::time::Instant::now();
        let json = Json::parse(&body)?;
        let Some(process) = json.get("process") else {
            return Err(PgprError::Data(
                "no `process` object in /metrics?format=json — is the server running \
                 with --no-prof?"
                    .into(),
            ));
        };
        let num = |k: &str| process.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        let cpu_total = num("cpu_seconds");
        let threads: std::collections::BTreeMap<String, f64> = process
            .get("threads")
            .and_then(|t| t.as_obj())
            .map(|m| m.iter().map(|(k, v)| (k.clone(), v.as_f64().unwrap_or(0.0))).collect())
            .unwrap_or_default();
        let wall = prev.as_ref().map(|(t, _, _)| now.duration_since(*t).as_secs_f64());
        let util = match (&prev, wall) {
            (Some((_, _, prev_cpu)), Some(w)) if w > 0.0 => {
                format!("  util {:.0}%", (cpu_total - prev_cpu).max(0.0) / w * 100.0)
            }
            _ => String::new(),
        };
        println!(
            "{}  rss {:.1} MiB  heap {:.1}/{:.1} MiB  fds {}  conns {}  cpu {cpu_total:.1}s{util}",
            c.addr,
            num("rss_bytes") / (1024.0 * 1024.0),
            num("heap_live_bytes") / (1024.0 * 1024.0),
            num("heap_peak_bytes") / (1024.0 * 1024.0),
            num("open_fds") as u64,
            num("open_connections") as u64,
        );
        let mut rows: Vec<(String, f64, Option<f64>)> = threads
            .iter()
            .map(|(name, &cpu)| {
                let util = match (&prev, wall) {
                    (Some((_, old, _)), Some(w)) if w > 0.0 => {
                        Some((cpu - old.get(name).copied().unwrap_or(0.0)).max(0.0) / w)
                    }
                    _ => None,
                };
                (name.clone(), cpu, util)
            })
            .collect();
        // Busiest first: current utilization, then cumulative CPU.
        rows.sort_by(|a, b| {
            b.2.unwrap_or(0.0).total_cmp(&a.2.unwrap_or(0.0)).then(b.1.total_cmp(&a.1))
        });
        for (name, cpu, util) in rows {
            match util {
                Some(u) => println!("  {name:<20} {cpu:>9.2}s  {:>5.1}%", u * 100.0),
                None => println!("  {name:<20} {cpu:>9.2}s"),
            }
        }
        iter += 1;
        if c.iters != 0 && iter >= c.iters {
            return Ok(());
        }
        prev = Some((now, threads, cpu_total));
        std::thread::sleep(Duration::from_millis(c.interval_ms.max(1)));
    }
}

/// Top-level dispatch used by main().
pub fn dispatch() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest: Vec<String> = argv.iter().skip(1).cloned().collect();
    match sub {
        "experiment" => {
            let a = Args::new("pgpr experiment", "run a paper experiment")
                .switch("full", "paper-scale parameters (slow)")
                .flag(
                    "backend",
                    "sim",
                    "execution backend for parallel runs: sim | threads[:N]",
                )
                .parse_from(rest)?;
            let id = a
                .positionals()
                .first()
                .cloned()
                .unwrap_or_else(|| "all".to_string());
            let backend = BackendKind::parse(&a.get("backend"))?;
            cmd_experiment(&id, a.get_bool("full"), backend)
        }
        "data" => {
            let a = Args::new("pgpr data", "generate datasets")
                .flag("dataset", "aimpeak", "sarcos | aimpeak | emslp")
                .flag("train", "1000", "training rows")
                .flag("test", "200", "test rows")
                .flag("seed", "0", "seed")
                .flag("out", "results/data", "output directory")
                .parse_from(rest)?;
            cmd_data_gen(
                &a.get("dataset"),
                a.get_usize("train"),
                a.get_usize("test"),
                a.get_usize("seed") as u64,
                &a.get("out"),
            )
        }
        "eval" => {
            let a = Args::new("pgpr eval", "fit + evaluate LMA on CSV data")
                .flag(
                    "train-csv",
                    "",
                    "training data (x0..xd-1, y header); required without --artifact",
                )
                .required("test-csv", "test data (x0..xd-1, y header)")
                .flag(
                    "artifact",
                    "",
                    "name=path of a saved snapshot: score it on --test-csv without refitting",
                )
                .flag("blocks", "8", "M — number of blocks")
                .flag("order", "1", "B — Markov order")
                .flag("support", "128", "|S| — support set size")
                .flag("seed", "0", "seed")
                .flag("out", "results/eval_predictions.csv", "prediction output CSV")
                .parse_from(rest)?;
            let artifact = a.get("artifact");
            if !artifact.is_empty() {
                return cmd_eval_artifact(&artifact, &a.get("test-csv"), &a.get("out"));
            }
            let train_csv = a.get("train-csv");
            if train_csv.is_empty() {
                return Err(PgprError::Config(
                    "eval: --train-csv is required without --artifact".into(),
                ));
            }
            cmd_eval(
                &train_csv,
                &a.get("test-csv"),
                a.get_usize("blocks"),
                a.get_usize("order"),
                a.get_usize("support"),
                a.get_usize("seed") as u64,
                &a.get("out"),
            )
        }
        "fit" => {
            let a = Args::new("pgpr fit", "fit a serving engine and save it as a model artifact")
                .flag("dataset", "aimpeak", "sarcos | aimpeak | emslp")
                .flag("train", "1000", "training rows")
                .flag("seed", "0", "seed")
                .flag(
                    "backend",
                    "centralized",
                    "prediction engine: centralized | sim | threads[:N]",
                )
                .flag("blocks", "0", "M — number of blocks (0 = auto from |D|)")
                .flag("order", "1", "B — Markov order (clamped to M−1)")
                .flag("support", "0", "|S| — support set size (0 = auto from |D|)")
                .required("save", "artifact output path, e.g. model.pgpr")
                .switch("profile", "print the per-phase fit profiler breakdown")
                .parse_from(rest)?;
            cmd_fit(&FitCmd {
                dataset: a.get("dataset"),
                train: a.get_usize("train"),
                seed: a.get_usize("seed") as u64,
                backend: a.get("backend"),
                blocks: a.get_usize("blocks"),
                order: a.get_usize("order"),
                support: a.get_usize("support"),
                save: a.get("save"),
                profile: a.get_bool("profile"),
            })
        }
        "serve" => {
            let a = Args::new("pgpr serve", "batched prediction service (HTTP or stdin)")
                .flag("dataset", "aimpeak", "sarcos | aimpeak | emslp")
                .flag("train", "1000", "training rows")
                .flag("batch", "16", "micro-batch size in rows")
                .flag("seed", "0", "seed")
                .flag(
                    "backend",
                    "centralized",
                    "prediction engine: centralized | sim | threads[:N]",
                )
                .multi(
                    "model",
                    "name=path of a saved artifact (repeatable); boots from snapshots without touching training data",
                )
                .flag("max-models", "8", "registry capacity for runtime PUT /models loads")
                .flag(
                    "listen",
                    "",
                    "HTTP listen address, e.g. 127.0.0.1:8080 (port 0 = ephemeral); empty = stdin line protocol",
                )
                .flag("workers", "4", "HTTP connection worker threads")
                .flag(
                    "max-delay-us",
                    "2000",
                    "partial-batch flush deadline in microseconds; 0 = no batching delay. \
                     In stdin mode expiry is only checked when the next input line arrives",
                )
                .flag("queue", "1024", "bounded request queue capacity (full ⇒ 503)")
                .flag(
                    "slo-ms",
                    "0",
                    "admission SLO in ms: shed with 503 + Retry-After when the predicted \
                     queue delay exceeds it (0 = off; per-model override via \
                     --model name=path,slo=MS,weight=W)",
                )
                .flag(
                    "default-deadline-ms",
                    "0",
                    "end-to-end deadline applied to requests without an X-Deadline-Ms \
                     header; expired requests are shed before reaching the engine (0 = none)",
                )
                .flag(
                    "observe-max-rows",
                    "1048576",
                    "observation rows buffered per model before POST …/observe returns \
                     429 backpressure instead of growing without bound",
                )
                .switch("no-keepalive", "one request per connection (legacy Connection: close)")
                .flag("idle-timeout-ms", "5000", "keep-alive idle timeout")
                .flag("max-conn-requests", "1000", "requests served per connection before close")
                .flag(
                    "observe-flush-rows",
                    "1",
                    "observed rows buffered per model before an incremental update publishes a new generation",
                )
                .switch(
                    "resnapshot",
                    "rewrite a model's artifact in place after each published online update",
                )
                .flag(
                    "observe-score",
                    "sample:16",
                    "prequential quality scoring of observed rows before they are \
                     absorbed: off | sample:K | all",
                )
                .flag(
                    "quality-window",
                    "1024",
                    "sliding quality window capacity in scored rows (rolling RMSE/MNLP/coverage)",
                )
                .flag(
                    "drift-threshold",
                    "1",
                    "fire a drift_detected event when windowed MNLP exceeds the \
                     artifact's fit-time baseline by this much",
                )
                .switch(
                    "f32-u",
                    "reduced-precision serve: f32 U-side context tensors with f64 \
                     accumulation (mean within 1e-5 relative of the f64 path; \
                     centralized engines only)",
                )
                .switch(
                    "no-trace",
                    "disable request-scoped stage tracing (histograms, ring buffer, ?trace=1)",
                )
                .flag("trace-ring", "256", "per-model trace ring capacity (last N requests)")
                .flag(
                    "slow-request-us",
                    "0",
                    "log a structured slow_request event for requests at or above this \
                     latency in microseconds (0 = off)",
                )
                .switch(
                    "no-prof",
                    "disable the continuous resource profiler (sampler thread, \
                     /debug/prof, process gauges, per-thread CPU counters)",
                )
                .flag("prof-interval-ms", "1000", "resource sampler cadence in milliseconds")
                .flag("prof-ring", "256", "profiler sample ring capacity (last N samples)")
                .parse_from(rest)?;
            let opts = ServeOptions {
                listen: a.get("listen"),
                workers: a.get_usize("workers"),
                batch_size: a.get_usize("batch"),
                max_delay_us: a.get_usize("max-delay-us") as u64,
                queue_capacity: a.get_usize("queue"),
                keep_alive: !a.get_bool("no-keepalive"),
                idle_timeout_ms: a.get_usize("idle-timeout-ms") as u64,
                max_conn_requests: a.get_usize("max-conn-requests"),
                f32_u: a.get_bool("f32-u"),
                trace: !a.get_bool("no-trace"),
                trace_ring: a.get_usize("trace-ring"),
                slow_request_us: a.get_usize("slow-request-us") as u64,
                slo_ms: a.get_usize("slo-ms") as u64,
                default_deadline_ms: a.get_usize("default-deadline-ms") as u64,
                prof: !a.get_bool("no-prof"),
                prof_interval_ms: a.get_usize("prof-interval-ms") as u64,
                prof_ring: a.get_usize("prof-ring"),
            };
            cmd_serve(&ServeCmd {
                dataset: a.get("dataset"),
                train: a.get_usize("train"),
                seed: a.get_usize("seed") as u64,
                backend: a.get("backend"),
                opts,
                models: a.get_multi("model"),
                max_models: a.get_usize("max-models"),
                observe_flush_rows: a.get_usize("observe-flush-rows"),
                resnapshot: a.get_bool("resnapshot"),
                observe_score: a.get("observe-score"),
                quality_window: a.get_usize("quality-window"),
                drift_threshold: a.get_f64("drift-threshold"),
                observe_max_rows: a.get_usize("observe-max-rows"),
            })
        }
        "observe" => {
            let a = Args::new("pgpr observe", "replay an observation stream into a served model")
                .required("addr", "target host:port of a running `pgpr serve --listen`")
                .flag("model", "default", "registry model name to stream into")
                .required("csv", "observation CSV (x0..xd-1, y header)")
                .flag("batch-rows", "64", "rows per observe request")
                .switch(
                    "buffer",
                    "buffer intermediate batches server-side; publish one update at the end",
                )
                .flag("limit", "0", "replay at most this many rows (0 = all)")
                .parse_from(rest)?;
            cmd_observe(&ObserveCmd {
                addr: a.get("addr"),
                model: a.get("model"),
                csv: a.get("csv"),
                batch_rows: a.get_usize("batch-rows"),
                buffer: a.get_bool("buffer"),
                limit: a.get_usize("limit"),
            })
        }
        "loadtest" => {
            let a = Args::new("pgpr loadtest", "closed-loop load generator for the HTTP service")
                .flag(
                    "addr",
                    "",
                    "target host:port of a running `pgpr serve --listen`; empty = boot an in-process server",
                )
                .flag("dataset", "aimpeak", "self-mode dataset")
                .flag("train", "600", "self-mode training rows")
                .flag("seed", "0", "seed (model fit and query generation)")
                .flag(
                    "backend",
                    "threads:0",
                    "self-mode engine: centralized | sim | threads[:N]",
                )
                .multi(
                    "model",
                    "registry model name to target (repeatable: traffic round-robins the names; self mode fits one variant per name)",
                )
                .multi(
                    "artifact",
                    "self-mode name=path artifact to serve instead of fitting (repeatable)",
                )
                .flag("mode", "both", "connection mode: keepalive | close | both")
                .flag(
                    "rate",
                    "0",
                    "open-loop arrival rate in req/s (adds a coordinated-omission-corrected pass; 0 = closed-loop only)",
                )
                .flag("batch", "16", "self-mode micro-batch size")
                .flag("workers", "4", "self-mode HTTP worker threads")
                .flag("max-delay-us", "2000", "self-mode flush deadline (µs)")
                .flag("queue", "1024", "self-mode queue capacity")
                .flag(
                    "slo-ms",
                    "0",
                    "self-mode admission SLO in ms (shed with 503 + Retry-After; 0 = off)",
                )
                .flag("concurrency", "8", "closed-loop client threads")
                .flag("requests", "200", "total requests to send")
                .flag("rows", "1", "rows per request")
                .flag("out", "BENCH_serve_latency.json", "output record path")
                .switch("no-trace", "self-mode: serve with stage tracing disabled")
                .switch("no-prof", "self-mode: serve with the resource profiler disabled")
                .parse_from(rest)?;
            cmd_loadtest(&LoadtestCmd {
                addr: a.get("addr"),
                dataset: a.get("dataset"),
                train: a.get_usize("train"),
                seed: a.get_usize("seed") as u64,
                backend: a.get("backend"),
                opts: ServeOptions {
                    listen: "127.0.0.1:0".into(),
                    workers: a.get_usize("workers"),
                    batch_size: a.get_usize("batch"),
                    max_delay_us: a.get_usize("max-delay-us") as u64,
                    queue_capacity: a.get_usize("queue"),
                    trace: !a.get_bool("no-trace"),
                    slo_ms: a.get_usize("slo-ms") as u64,
                    prof: !a.get_bool("no-prof"),
                    ..ServeOptions::default()
                },
                concurrency: a.get_usize("concurrency"),
                requests: a.get_usize("requests"),
                rows: a.get_usize("rows"),
                rate: a.get_f64("rate"),
                out: a.get("out"),
                mode: a.get("mode"),
                models: a.get_multi("model"),
                artifacts: a.get_multi("artifact"),
            })
        }
        "top" => {
            let a = Args::new("pgpr top", "poll a live server's resource profile")
                .required("addr", "target host:port of a running `pgpr serve --listen`")
                .flag("interval-ms", "1000", "poll cadence in milliseconds")
                .flag("iters", "0", "number of polls (0 = until interrupted)")
                .parse_from(rest)?;
            cmd_top(&TopCmd {
                addr: a.get("addr"),
                interval_ms: a.get_usize("interval-ms") as u64,
                iters: a.get_usize("iters"),
            })
        }
        "bench-info" => cmd_bench_info(),
        _ => {
            println!(
                "pgpr — Parallel GP Regression (LMA, AAAI 2015 reproduction)\n\n\
                 USAGE:\n  pgpr experiment <table1a|table1b|table2|table3|fig2|fig6|ablation|all> [--full] [--backend sim|threads[:N]]\n  \
                 pgpr data --dataset aimpeak --train 1000 --test 200 --out dir/\n  \
                 pgpr eval --train-csv train.csv --test-csv test.csv [--blocks 8 --order 1 --support 128]\n  \
                 pgpr eval --artifact name=model.pgpr --test-csv test.csv (warm-start: score a snapshot, no refit)\n  \
                 pgpr fit --dataset aimpeak --train 1000 --save model.pgpr [--blocks 0 --order 1 --support 0] [--profile]\n  \
                 pgpr serve --dataset aimpeak --train 1000 --batch 16 [--backend centralized|sim|threads[:N]]\n  \
                 \u{20}          [--model name=model.pgpr[,slo=MS][,weight=W] ...] [--listen 127.0.0.1:8080 --workers 4 --queue 1024]\n  \
                 \u{20}          [--slo-ms 0 --default-deadline-ms 0 --observe-max-rows 1048576] (overload admission control)\n  \
                 \u{20}          [--no-prof --prof-interval-ms 1000 --prof-ring 256] (resource profiler)\n  \
                 pgpr observe --addr HOST:PORT --csv data.csv [--model default --batch-rows 64 --buffer --limit 0]\n  \
                 pgpr loadtest [--addr HOST:PORT | --dataset aimpeak --train 600 --backend threads:0]\n  \
                 \u{20}          [--model NAME ...] [--artifact name=model.pgpr ...] [--mode both|keepalive|close]\n  \
                 \u{20}          [--rate 0] [--concurrency 8 --requests 200 --rows 1 --out BENCH_serve_latency.json]\n  \
                 pgpr top --addr HOST:PORT [--interval-ms 1000 --iters 0]\n  \
                 pgpr bench-info\n"
            );
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_gen_roundtrip() {
        let dir = std::env::temp_dir().join("pgpr_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        cmd_data_gen("aimpeak", 50, 10, 1, dir.to_str().unwrap()).unwrap();
        let (x, y) = load_xy_csv(dir.join("aimpeak-sim_train.csv").to_str().unwrap()).unwrap();
        assert_eq!(x.rows(), 50);
        assert_eq!(x.cols(), 5);
        assert_eq!(y.len(), 50);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn unknown_experiment_rejected() {
        assert!(cmd_experiment("bogus", false, BackendKind::Sim).is_err());
    }

    #[test]
    fn model_spec_parsing() {
        assert_eq!(
            parse_model_spec("alpha=/tmp/a.pgpr").unwrap(),
            ("alpha".to_string(), "/tmp/a.pgpr".to_string())
        );
        assert_eq!(
            parse_model_spec(" b = path with spaces ").unwrap(),
            ("b".to_string(), "path with spaces".to_string())
        );
        assert!(parse_model_spec("noequals").is_err());
        assert!(parse_model_spec("=path").is_err());
        assert!(parse_model_spec("name=").is_err());
    }

    #[test]
    fn model_spec_policy_parsing() {
        use std::time::Duration;
        // Bare spec inherits the server-wide SLO and weight 1.
        let (name, path, p) = parse_model_spec_policy("a=/tmp/a.pgpr", 25).unwrap();
        assert_eq!((name.as_str(), path.as_str()), ("a", "/tmp/a.pgpr"));
        assert_eq!(p.slo, Some(Duration::from_millis(25)));
        assert_eq!(p.weight, 1);
        // Per-model options override; slo=0 disables the inherited SLO.
        let (_, _, p) = parse_model_spec_policy("a=/tmp/a.pgpr, slo=40 ,weight=3", 25).unwrap();
        assert_eq!(p.slo, Some(Duration::from_millis(40)));
        assert_eq!(p.weight, 3);
        let (_, _, p) = parse_model_spec_policy("a=/tmp/a.pgpr,slo=0", 25).unwrap();
        assert_eq!(p.slo, None);
        // Unknown or malformed options are rejected, not ignored.
        assert!(parse_model_spec_policy("a=/tmp/a.pgpr,turbo=1", 0).is_err());
        assert!(parse_model_spec_policy("a=/tmp/a.pgpr,slo=soon", 0).is_err());
    }

    #[test]
    fn fit_saves_a_loadable_artifact() {
        let dir = std::env::temp_dir().join("pgpr_fit_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let save = dir.join("m.pgpr");
        let save = save.to_str().unwrap().to_string();
        cmd_fit(&FitCmd {
            dataset: "aimpeak".into(),
            train: 160,
            seed: 5,
            backend: "centralized".into(),
            blocks: 2,
            order: 1,
            support: 16,
            save: save.clone(),
            profile: true,
        })
        .unwrap();
        let engine = artifact::load_engine(&save).unwrap();
        assert_eq!(engine.backend_name(), "centralized");
        assert_eq!(engine.core().m(), 2);
        // The fit driver stamps a held-out quality baseline and the
        // artifact round-trip must preserve it.
        let b = engine.core().quality_baseline.expect("fit stamps a quality baseline");
        assert!(b.rows > 0 && b.rmse.is_finite() && b.mnlp.is_finite());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn bad_loadtest_mode_rejected() {
        let cmd = LoadtestCmd { mode: "sometimes".into(), ..LoadtestCmd::default() };
        assert!(run_loadtest(&cmd).is_err());
    }
}
