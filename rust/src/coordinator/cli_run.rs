//! Subcommand implementations for the `pgpr` binary.

use std::io::BufRead;

use crate::config::{BackendKind, ClusterConfig, LmaConfig, PartitionStrategy};
use crate::coordinator::service::{PredictionService, Request, ServeEngine};
use crate::experiments::{ablation, common::Workload, fig2, fig6, table1, table2, table3};
use crate::lma::parallel::ParallelLma;
use crate::lma::LmaRegressor;
use crate::util::cli::Args;
use crate::util::csv::CsvTable;
use crate::util::error::{PgprError, Result};

/// `pgpr experiment <id> [--full] [--backend sim|threads[:N]]`.
///
/// `backend` selects the execution backend for experiments with parallel
/// runs (currently Table 2); the others are backend-independent.
pub fn cmd_experiment(id: &str, full: bool, backend: BackendKind) -> Result<()> {
    match id {
        "table1a" => {
            let p = if full {
                table1::Table1Params::full_for(Workload::Sarcos)
            } else {
                table1::Table1Params::default_for(Workload::Sarcos)
            };
            table1::run(&p)?;
        }
        "table1b" => {
            let p = if full {
                table1::Table1Params::full_for(Workload::Aimpeak)
            } else {
                table1::Table1Params::default_for(Workload::Aimpeak)
            };
            table1::run(&p)?;
        }
        "table2" => {
            let mut p =
                if full { table2::Table2Params::full() } else { table2::Table2Params::default() };
            p.backend = backend;
            table2::run(&p)?;
        }
        "table3" => {
            let p = if full { table3::Table3Params::full() } else { table3::Table3Params::default() };
            table3::run(&p)?;
        }
        "fig2" => {
            let p = if full { fig2::Fig2Params::full() } else { fig2::Fig2Params::default() };
            fig2::run(&p)?;
        }
        "fig6" => {
            fig6::run(42)?;
        }
        "ablation" => {
            ablation::run(42)?;
        }
        "all" => {
            for id in ["table1a", "table1b", "table2", "table3", "fig2", "fig6", "ablation"] {
                cmd_experiment(id, full, backend)?;
            }
        }
        other => {
            return Err(PgprError::Config(format!(
                "unknown experiment `{other}` (try table1a, table1b, table2, table3, fig2, fig6, ablation, all)"
            )))
        }
    }
    Ok(())
}

/// `pgpr data gen` — write train/test CSVs.
pub fn cmd_data_gen(dataset: &str, train: usize, test: usize, seed: u64, out: &str) -> Result<()> {
    let w = Workload::parse(dataset)?;
    let ds = w.generate(train, test, seed)?;
    ds.validate()?;
    for (tag, x, y) in [
        ("train", &ds.train_x, &ds.train_y),
        ("test", &ds.test_x, &ds.test_y),
    ] {
        let mut header: Vec<String> = (0..ds.dim()).map(|j| format!("x{j}")).collect();
        header.push("y".into());
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = CsvTable::new(&header_refs);
        for i in 0..x.rows() {
            let mut row: Vec<f64> = x.row(i).to_vec();
            row.push(y[i]);
            t.push_nums(&row);
        }
        let path = format!("{out}/{}_{tag}.csv", ds.name);
        t.write_path(&path)?;
        println!("wrote {path} ({} rows)", x.rows());
    }
    Ok(())
}

/// Load a dataset CSV written by `cmd_data_gen`.
pub fn load_xy_csv(path: &str) -> Result<(crate::linalg::matrix::Mat, Vec<f64>)> {
    let t = CsvTable::read_path(path)?;
    let d = t.header.len() - 1;
    if t.header.last().map(|s| s.as_str()) != Some("y") {
        return Err(PgprError::Data(format!("{path}: last column must be `y`")));
    }
    let n = t.rows.len();
    let mut x = crate::linalg::matrix::Mat::zeros(n, d);
    let mut y = vec![0.0; n];
    for (i, row) in t.rows.iter().enumerate() {
        for j in 0..d {
            x.set(i, j, row[j].parse().map_err(|_| PgprError::Data(format!("bad cell {}", row[j])))?);
        }
        y[i] = row[d].parse().map_err(|_| PgprError::Data(format!("bad cell {}", row[d])))?;
    }
    Ok((x, y))
}

/// `pgpr eval` — fit LMA on a training CSV, evaluate on a test CSV,
/// write per-point predictions and print metrics.
pub fn cmd_eval(
    train_csv: &str,
    test_csv: &str,
    m: usize,
    b: usize,
    s: usize,
    seed: u64,
    out: &str,
) -> Result<()> {
    let (train_x, train_y) = load_xy_csv(train_csv)?;
    let (test_x, test_y) = load_xy_csv(test_csv)?;
    let ds = crate::data::Dataset {
        name: "csv".into(),
        train_x,
        train_y,
        test_x,
        test_y,
    };
    ds.validate()?;
    let hyp = crate::experiments::common::learn_hypers(&ds, 512.min(ds.train_x.rows()), seed)?;
    let cfg = LmaConfig {
        num_blocks: m,
        markov_order: b,
        support_size: s,
        seed,
        partition: PartitionStrategy::KMeans { iters: 10 },
        use_pjrt: false,
    };
    let (model, fit_secs) =
        crate::util::timer::time_it(|| LmaRegressor::fit(&ds.train_x, &ds.train_y, &hyp, &cfg));
    let model = model?;
    let (pred, pred_secs) = crate::util::timer::time_it(|| model.predict(&ds.test_x));
    let pred = pred?;
    let rmse = crate::metrics::rmse(&pred.mean, &ds.test_y);
    let mnlp = crate::metrics::mnlp(&pred.mean, &pred.var, &ds.test_y);
    println!(
        "LMA(M={m}, B={b}, |S|={s}): rmse {rmse:.6}  mnlp {mnlp:.4}  fit {fit_secs:.2}s  predict {pred_secs:.2}s"
    );
    let mut t = CsvTable::new(&["y_true", "mean", "var"]);
    for i in 0..pred.mean.len() {
        t.push_nums(&[ds.test_y[i], pred.mean[i], pred.var[i]]);
    }
    t.write_path(out)?;
    println!("wrote {out}");
    Ok(())
}

/// `pgpr serve` — line protocol: `predict v1,v2,...` → `id mean var`;
/// `flush` forces a partial batch; EOF flushes and prints stats.
///
/// `backend` picks the prediction engine: `centralized` (single-process
/// LMA), or `sim` / `threads[:N]` for the parallel engine on the
/// corresponding `cluster::Backend`.
pub fn cmd_serve(dataset: &str, train: usize, batch: usize, seed: u64, backend: &str) -> Result<()> {
    let w = Workload::parse(dataset)?;
    let ds = w.generate(train, train / 4, seed)?;
    let hyp = crate::experiments::common::quick_hypers(&ds);
    let m = (train / 128).clamp(2, 32);
    let cfg = LmaConfig {
        num_blocks: m,
        markov_order: 1,
        support_size: (train / 16).clamp(8, 512),
        seed,
        partition: PartitionStrategy::KMeans { iters: 8 },
        use_pjrt: false,
    };
    let engine = if backend == "centralized" {
        ServeEngine::Centralized(LmaRegressor::fit(&ds.train_x, &ds.train_y, &hyp, &cfg)?)
    } else {
        let kind = BackendKind::parse(backend)?;
        let cc = ClusterConfig::gigabit(1, m).with_backend(kind);
        ServeEngine::Parallel(ParallelLma::fit(&ds.train_x, &ds.train_y, &hyp, &cfg, &cc)?)
    };
    let mut svc = PredictionService::with_engine(engine, batch)?;
    eprintln!(
        "serving {} (dim {}, M={m}, batch {batch}, backend {backend}); protocol: `predict v1,v2,...` | `flush` | EOF",
        ds.name,
        ds.dim()
    );
    let stdin = std::io::stdin();
    let mut next_id = 0u64;
    for line in stdin.lock().lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "flush" {
            for r in svc.flush()? {
                println!("{} {:.6} {:.6}", r.id, r.mean, r.var);
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("predict ") {
            let x: std::result::Result<Vec<f64>, _> =
                rest.split(',').map(|s| s.trim().parse::<f64>()).collect();
            let x = x.map_err(|_| PgprError::Data(format!("bad request `{line}`")))?;
            next_id += 1;
            for r in svc.submit(Request { id: next_id, x })? {
                println!("{} {:.6} {:.6}", r.id, r.mean, r.var);
            }
        } else {
            eprintln!("unknown command: {line}");
        }
    }
    for r in svc.flush()? {
        println!("{} {:.6} {:.6}", r.id, r.mean, r.var);
    }
    eprintln!(
        "served {} requests in {} batches; mean latency {:.4}s; throughput {:.1} req/s",
        svc.served,
        svc.batches,
        svc.mean_latency(),
        svc.throughput()
    );
    Ok(())
}

/// `pgpr bench-info`: report artifact availability.
pub fn cmd_bench_info() -> Result<()> {
    match crate::runtime::artifacts::ArtifactLibrary::try_default() {
        Some(lib) => {
            println!("artifacts: loaded {} entries", lib.entries().len());
            for e in lib.entries() {
                println!("  {} {}x{} d={} ({})", e.name, e.n1, e.n2, e.d, e.file);
            }
        }
        None => println!("artifacts: not built (run `make artifacts`); native path active"),
    }
    Ok(())
}

/// Top-level dispatch used by main().
pub fn dispatch() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest: Vec<String> = argv.iter().skip(1).cloned().collect();
    match sub {
        "experiment" => {
            let a = Args::new("pgpr experiment", "run a paper experiment")
                .switch("full", "paper-scale parameters (slow)")
                .flag(
                    "backend",
                    "sim",
                    "execution backend for parallel runs: sim | threads[:N]",
                )
                .parse_from(rest)?;
            let id = a
                .positionals()
                .first()
                .cloned()
                .unwrap_or_else(|| "all".to_string());
            let backend = BackendKind::parse(&a.get("backend"))?;
            cmd_experiment(&id, a.get_bool("full"), backend)
        }
        "data" => {
            let a = Args::new("pgpr data", "generate datasets")
                .flag("dataset", "aimpeak", "sarcos | aimpeak | emslp")
                .flag("train", "1000", "training rows")
                .flag("test", "200", "test rows")
                .flag("seed", "0", "seed")
                .flag("out", "results/data", "output directory")
                .parse_from(rest)?;
            cmd_data_gen(
                &a.get("dataset"),
                a.get_usize("train"),
                a.get_usize("test"),
                a.get_usize("seed") as u64,
                &a.get("out"),
            )
        }
        "eval" => {
            let a = Args::new("pgpr eval", "fit + evaluate LMA on CSV data")
                .required("train-csv", "training data (x0..xd-1, y header)")
                .required("test-csv", "test data (same schema)")
                .flag("blocks", "8", "M — number of blocks")
                .flag("order", "1", "B — Markov order")
                .flag("support", "128", "|S| — support set size")
                .flag("seed", "0", "seed")
                .flag("out", "results/eval_predictions.csv", "prediction output CSV")
                .parse_from(rest)?;
            cmd_eval(
                &a.get("train-csv"),
                &a.get("test-csv"),
                a.get_usize("blocks"),
                a.get_usize("order"),
                a.get_usize("support"),
                a.get_usize("seed") as u64,
                &a.get("out"),
            )
        }
        "serve" => {
            let a = Args::new("pgpr serve", "batched prediction service")
                .flag("dataset", "aimpeak", "sarcos | aimpeak | emslp")
                .flag("train", "1000", "training rows")
                .flag("batch", "16", "batch size")
                .flag("seed", "0", "seed")
                .flag(
                    "backend",
                    "centralized",
                    "prediction engine: centralized | sim | threads[:N]",
                )
                .parse_from(rest)?;
            cmd_serve(
                &a.get("dataset"),
                a.get_usize("train"),
                a.get_usize("batch"),
                a.get_usize("seed") as u64,
                &a.get("backend"),
            )
        }
        "bench-info" => cmd_bench_info(),
        _ => {
            println!(
                "pgpr — Parallel GP Regression (LMA, AAAI 2015 reproduction)\n\n\
                 USAGE:\n  pgpr experiment <table1a|table1b|table2|table3|fig2|fig6|ablation|all> [--full] [--backend sim|threads[:N]]\n  \
                 pgpr data --dataset aimpeak --train 1000 --test 200 --out dir/\n  \
                 pgpr eval --train-csv train.csv --test-csv test.csv [--blocks 8 --order 1 --support 128]\n  \
                 pgpr serve --dataset aimpeak --train 1000 --batch 16 [--backend centralized|sim|threads[:N]]\n  \
                 pgpr bench-info\n"
            );
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_gen_roundtrip() {
        let dir = std::env::temp_dir().join("pgpr_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        cmd_data_gen("aimpeak", 50, 10, 1, dir.to_str().unwrap()).unwrap();
        let (x, y) = load_xy_csv(dir.join("aimpeak-sim_train.csv").to_str().unwrap()).unwrap();
        assert_eq!(x.rows(), 50);
        assert_eq!(x.cols(), 5);
        assert_eq!(y.len(), 50);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn unknown_experiment_rejected() {
        assert!(cmd_experiment("bogus", false, BackendKind::Sim).is_err());
    }
}
