//! Subcommand implementations for the `pgpr` binary.

use std::io::BufRead;
use std::time::Duration;

use crate::config::{BackendKind, ClusterConfig, LmaConfig, PartitionStrategy, ServeOptions};
use crate::coordinator::service::{PredictionService, Request, ServeEngine};
use crate::experiments::{ablation, common::Workload, fig2, fig6, table1, table2, table3};
use crate::lma::parallel::ParallelLma;
use crate::lma::LmaRegressor;
use crate::server::http::Server;
use crate::server::loadgen;
use crate::util::cli::Args;
use crate::util::csv::CsvTable;
use crate::util::error::{PgprError, Result};
use crate::util::json::Json;

/// `pgpr experiment <id> [--full] [--backend sim|threads[:N]]`.
///
/// `backend` selects the execution backend for experiments with parallel
/// runs (currently Table 2); the others are backend-independent.
pub fn cmd_experiment(id: &str, full: bool, backend: BackendKind) -> Result<()> {
    match id {
        "table1a" => {
            let p = if full {
                table1::Table1Params::full_for(Workload::Sarcos)
            } else {
                table1::Table1Params::default_for(Workload::Sarcos)
            };
            table1::run(&p)?;
        }
        "table1b" => {
            let p = if full {
                table1::Table1Params::full_for(Workload::Aimpeak)
            } else {
                table1::Table1Params::default_for(Workload::Aimpeak)
            };
            table1::run(&p)?;
        }
        "table2" => {
            let mut p =
                if full { table2::Table2Params::full() } else { table2::Table2Params::default() };
            p.backend = backend;
            table2::run(&p)?;
        }
        "table3" => {
            let p = if full {
                table3::Table3Params::full()
            } else {
                table3::Table3Params::default()
            };
            table3::run(&p)?;
        }
        "fig2" => {
            let p = if full { fig2::Fig2Params::full() } else { fig2::Fig2Params::default() };
            fig2::run(&p)?;
        }
        "fig6" => {
            fig6::run(42)?;
        }
        "ablation" => {
            ablation::run(42)?;
        }
        "all" => {
            for id in ["table1a", "table1b", "table2", "table3", "fig2", "fig6", "ablation"] {
                cmd_experiment(id, full, backend)?;
            }
        }
        other => {
            return Err(PgprError::Config(format!(
                "unknown experiment `{other}` (try table1a, table1b, table2, table3, fig2, fig6, ablation, all)"
            )))
        }
    }
    Ok(())
}

/// `pgpr data gen` — write train/test CSVs.
pub fn cmd_data_gen(dataset: &str, train: usize, test: usize, seed: u64, out: &str) -> Result<()> {
    let w = Workload::parse(dataset)?;
    let ds = w.generate(train, test, seed)?;
    ds.validate()?;
    for (tag, x, y) in [
        ("train", &ds.train_x, &ds.train_y),
        ("test", &ds.test_x, &ds.test_y),
    ] {
        let mut header: Vec<String> = (0..ds.dim()).map(|j| format!("x{j}")).collect();
        header.push("y".into());
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = CsvTable::new(&header_refs);
        for i in 0..x.rows() {
            let mut row: Vec<f64> = x.row(i).to_vec();
            row.push(y[i]);
            t.push_nums(&row);
        }
        let path = format!("{out}/{}_{tag}.csv", ds.name);
        t.write_path(&path)?;
        println!("wrote {path} ({} rows)", x.rows());
    }
    Ok(())
}

/// Load a dataset CSV written by `cmd_data_gen`.
pub fn load_xy_csv(path: &str) -> Result<(crate::linalg::matrix::Mat, Vec<f64>)> {
    let t = CsvTable::read_path(path)?;
    let d = t.header.len() - 1;
    if t.header.last().map(|s| s.as_str()) != Some("y") {
        return Err(PgprError::Data(format!("{path}: last column must be `y`")));
    }
    let n = t.rows.len();
    let mut x = crate::linalg::matrix::Mat::zeros(n, d);
    let mut y = vec![0.0; n];
    for (i, row) in t.rows.iter().enumerate() {
        for j in 0..d {
            let v = row[j]
                .parse()
                .map_err(|_| PgprError::Data(format!("bad cell {}", row[j])))?;
            x.set(i, j, v);
        }
        y[i] = row[d].parse().map_err(|_| PgprError::Data(format!("bad cell {}", row[d])))?;
    }
    Ok((x, y))
}

/// `pgpr eval` — fit LMA on a training CSV, evaluate on a test CSV,
/// write per-point predictions and print metrics.
pub fn cmd_eval(
    train_csv: &str,
    test_csv: &str,
    m: usize,
    b: usize,
    s: usize,
    seed: u64,
    out: &str,
) -> Result<()> {
    let (train_x, train_y) = load_xy_csv(train_csv)?;
    let (test_x, test_y) = load_xy_csv(test_csv)?;
    let ds = crate::data::Dataset {
        name: "csv".into(),
        train_x,
        train_y,
        test_x,
        test_y,
    };
    ds.validate()?;
    let hyp = crate::experiments::common::learn_hypers(&ds, 512.min(ds.train_x.rows()), seed)?;
    let cfg = LmaConfig {
        num_blocks: m,
        markov_order: b,
        support_size: s,
        seed,
        partition: PartitionStrategy::KMeans { iters: 10 },
        use_pjrt: false,
    };
    let (model, fit_secs) =
        crate::util::timer::time_it(|| LmaRegressor::fit(&ds.train_x, &ds.train_y, &hyp, &cfg));
    let model = model?;
    let (pred, pred_secs) = crate::util::timer::time_it(|| model.predict(&ds.test_x));
    let pred = pred?;
    let rmse = crate::metrics::rmse(&pred.mean, &ds.test_y);
    let mnlp = crate::metrics::mnlp(&pred.mean, &pred.var, &ds.test_y);
    println!(
        "LMA(M={m}, B={b}, |S|={s}): rmse {rmse:.6}  mnlp {mnlp:.4}  fit {fit_secs:.2}s  predict {pred_secs:.2}s"
    );
    let mut t = CsvTable::new(&["y_true", "mean", "var"]);
    for i in 0..pred.mean.len() {
        t.push_nums(&[ds.test_y[i], pred.mean[i], pred.var[i]]);
    }
    t.write_path(out)?;
    println!("wrote {out}");
    Ok(())
}

/// `pgpr serve` parameters: which model to fit and how to front it.
#[derive(Clone, Debug)]
pub struct ServeCmd {
    pub dataset: String,
    pub train: usize,
    pub seed: u64,
    /// `centralized` | `sim` | `threads[:N]`.
    pub backend: String,
    /// HTTP/batching options; an empty `opts.listen` selects the stdin
    /// line protocol instead of HTTP.
    pub opts: ServeOptions,
}

/// Fit the serving engine the way `pgpr serve` always has: synthetic
/// workload, quick hypers, M scaled to |D|.
fn build_serve_engine(
    dataset: &str,
    train: usize,
    seed: u64,
    backend: &str,
) -> Result<(ServeEngine, String)> {
    let w = Workload::parse(dataset)?;
    let ds = w.generate(train, train / 4, seed)?;
    let hyp = crate::experiments::common::quick_hypers(&ds);
    let m = (train / 128).clamp(2, 32);
    let cfg = LmaConfig {
        num_blocks: m,
        markov_order: 1,
        support_size: (train / 16).clamp(8, 512),
        seed,
        partition: PartitionStrategy::KMeans { iters: 8 },
        use_pjrt: false,
    };
    let engine = if backend == "centralized" {
        ServeEngine::Centralized(LmaRegressor::fit(&ds.train_x, &ds.train_y, &hyp, &cfg)?)
    } else {
        let kind = BackendKind::parse(backend)?;
        let cc = ClusterConfig::gigabit(1, m).with_backend(kind);
        ServeEngine::Parallel(ParallelLma::fit(&ds.train_x, &ds.train_y, &hyp, &cfg, &cc)?)
    };
    Ok((engine, ds.name))
}

/// `pgpr serve` — HTTP mode (`--listen host:port`): boots the
/// `server::http` stack (acceptor, worker pool, micro-batcher) and runs
/// until stdin closes or a `quit` line arrives, then prints the metrics
/// summary. Stdin mode (`--listen ""`, the default): the legacy line
/// protocol `predict v1,v2,...` → `id mean var`, with `flush` forcing a
/// partial batch and EOF flushing and printing stats.
pub fn cmd_serve(c: &ServeCmd) -> Result<()> {
    let (engine, name) = build_serve_engine(&c.dataset, c.train, c.seed, &c.backend)?;
    if !c.opts.listen.is_empty() {
        return serve_http(c, engine, &name);
    }
    // Same semantics as the HTTP batcher: 0 = no batching delay (the
    // deadline is always already expired, so partial batches flush at
    // the first opportunity).
    let mut svc = PredictionService::with_engine(engine, c.opts.batch_size)?
        .with_max_delay(Duration::from_micros(c.opts.max_delay_us));
    eprintln!(
        "serving {} (dim {}, batch {}, backend {}); protocol: `predict v1,v2,...` | `flush` | EOF",
        name,
        svc.dim(),
        c.opts.batch_size,
        c.backend
    );
    let stdin = std::io::stdin();
    let mut next_id = 0u64;
    for line in stdin.lock().lines() {
        // Answer anything whose max_delay deadline lapsed while we
        // waited for input. Stdin blocks with no timeout, so this only
        // runs when the next line arrives — the hard deadline guarantee
        // is the HTTP batcher's (it waits with recv_timeout); here it
        // just keeps an interactive session from replaying stale rows.
        for r in svc.flush_expired()? {
            println!("{} {:.6} {:.6}", r.id, r.mean, r.var);
        }
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "flush" {
            for r in svc.flush()? {
                println!("{} {:.6} {:.6}", r.id, r.mean, r.var);
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("predict ") {
            let x: std::result::Result<Vec<f64>, _> =
                rest.split(',').map(|s| s.trim().parse::<f64>()).collect();
            let x = x.map_err(|_| PgprError::Data(format!("bad request `{line}`")))?;
            next_id += 1;
            for r in svc.submit(Request { id: next_id, x })? {
                println!("{} {:.6} {:.6}", r.id, r.mean, r.var);
            }
        } else {
            eprintln!("unknown command: {line}");
        }
    }
    for r in svc.flush()? {
        println!("{} {:.6} {:.6}", r.id, r.mean, r.var);
    }
    let (p50, p95, p99) = svc.latency_quantiles();
    eprintln!(
        "served {} requests in {} batches; latency mean {:.4}s p50 {:.4}s p95 {:.4}s p99 {:.4}s; throughput {:.1} req/s",
        svc.served,
        svc.batches,
        svc.mean_latency(),
        p50,
        p95,
        p99,
        svc.throughput()
    );
    Ok(())
}

fn serve_http(c: &ServeCmd, engine: ServeEngine, name: &str) -> Result<()> {
    let server = Server::start(engine, &c.opts)?;
    let addr = server.addr();
    eprintln!(
        "serving {name} on http://{addr} (backend {}, workers {}, batch {}, max-delay {}µs, queue {})",
        c.backend, c.opts.workers, c.opts.batch_size, c.opts.max_delay_us, c.opts.queue_capacity
    );
    eprintln!("endpoints: POST /predict  GET /healthz  GET /metrics — `quit` on stdin stops");
    // Machine-readable bound address on stdout so scripts can pick up
    // the ephemeral port from `--listen 127.0.0.1:0`.
    println!("listening {addr}");
    let stdin = std::io::stdin();
    let mut quit = false;
    for line in stdin.lock().lines() {
        let line = line?;
        if line.trim() == "quit" {
            quit = true;
            break;
        }
    }
    if !quit {
        // Stdin closed (detached/daemonized run, `… </dev/null &`):
        // keep serving until the process is killed.
        eprintln!("stdin closed; serving until the process is terminated");
        loop {
            std::thread::park();
        }
    }
    let metrics = server.shutdown();
    eprintln!("{}", metrics.summary());
    Ok(())
}

/// `pgpr loadtest` parameters.
#[derive(Clone, Debug)]
pub struct LoadtestCmd {
    /// Target `host:port`; empty = boot an in-process server first.
    pub addr: String,
    /// Self-mode model parameters (ignored when `addr` is set).
    pub dataset: String,
    pub train: usize,
    pub seed: u64,
    pub backend: String,
    pub opts: ServeOptions,
    /// Load shape.
    pub concurrency: usize,
    pub requests: usize,
    pub rows: usize,
    /// Output path of the machine-readable record.
    pub out: String,
}

impl Default for LoadtestCmd {
    fn default() -> Self {
        LoadtestCmd {
            addr: String::new(),
            dataset: "aimpeak".into(),
            train: 600,
            seed: 0,
            backend: "threads:0".into(),
            opts: ServeOptions { listen: "127.0.0.1:0".into(), ..ServeOptions::default() },
            concurrency: 8,
            requests: 200,
            rows: 1,
            out: "BENCH_serve_latency.json".into(),
        }
    }
}

/// Run the load test and return the `BENCH_serve_latency` record (also
/// used by `bench_serve_latency`). Self-contained mode fits an engine,
/// boots the HTTP stack on an ephemeral port, drives it and shuts it
/// down, embedding both client- and server-side quantiles.
pub fn run_loadtest(c: &LoadtestCmd) -> Result<Json> {
    let (addr, server) = if c.addr.is_empty() {
        let (engine, _name) = build_serve_engine(&c.dataset, c.train, c.seed, &c.backend)?;
        let mut opts = c.opts.clone();
        if opts.listen.is_empty() {
            opts.listen = "127.0.0.1:0".into();
        }
        let server = Server::start(engine, &opts)?;
        (server.addr().to_string(), Some(server))
    } else {
        (c.addr.clone(), None)
    };
    let dim = loadgen::fetch_dim(&addr)?;
    let lc = loadgen::LoadConfig {
        addr: addr.clone(),
        concurrency: c.concurrency,
        requests: c.requests,
        rows_per_request: c.rows,
        dim,
        seed: c.seed,
    };
    let report = loadgen::run(&lc)?;
    eprintln!("{}", report.render());
    let mode = if server.is_some() { "self" } else { "remote" };
    let mut fields: Vec<(&str, Json)> = vec![
        ("bench", Json::Str("serve_latency".into())),
        ("mode", Json::Str(mode.to_string())),
        ("addr", Json::Str(addr)),
        ("concurrency", Json::Num(c.concurrency as f64)),
        ("requests", Json::Num(c.requests as f64)),
        ("rows_per_request", Json::Num(c.rows as f64)),
        // Headline numbers duplicated at top level for easy extraction.
        ("throughput_rps", Json::Num(report.throughput_rps)),
        ("p50_s", Json::Num(report.p50_s)),
        ("p95_s", Json::Num(report.p95_s)),
        ("p99_s", Json::Num(report.p99_s)),
        ("client", report.to_json()),
    ];
    if let Some(server) = server {
        // Engine/batcher configuration is only known (and only true) in
        // self-contained mode; a remote server's settings are its own.
        fields.push(("backend", Json::Str(c.backend.clone())));
        fields.push(("dataset", Json::Str(c.dataset.clone())));
        fields.push(("train", Json::Num(c.train as f64)));
        fields.push(("batch_size", Json::Num(c.opts.batch_size as f64)));
        fields.push(("max_delay_us", Json::Num(c.opts.max_delay_us as f64)));
        let metrics = server.shutdown();
        eprintln!("{}", metrics.summary());
        fields.push(("server", metrics.to_json()));
    }
    Ok(Json::obj(fields))
}

/// `pgpr loadtest` — drive a serving stack and write
/// `BENCH_serve_latency.json`.
pub fn cmd_loadtest(c: &LoadtestCmd) -> Result<()> {
    let record = run_loadtest(c)?;
    crate::util::bench::write_json_record(&c.out, &record)?;
    println!("wrote {}", c.out);
    Ok(())
}

/// `pgpr bench-info`: report artifact availability.
pub fn cmd_bench_info() -> Result<()> {
    match crate::runtime::artifacts::ArtifactLibrary::try_default() {
        Some(lib) => {
            println!("artifacts: loaded {} entries", lib.entries().len());
            for e in lib.entries() {
                println!("  {} {}x{} d={} ({})", e.name, e.n1, e.n2, e.d, e.file);
            }
        }
        None => println!("artifacts: not built (run `make artifacts`); native path active"),
    }
    Ok(())
}

/// Top-level dispatch used by main().
pub fn dispatch() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest: Vec<String> = argv.iter().skip(1).cloned().collect();
    match sub {
        "experiment" => {
            let a = Args::new("pgpr experiment", "run a paper experiment")
                .switch("full", "paper-scale parameters (slow)")
                .flag(
                    "backend",
                    "sim",
                    "execution backend for parallel runs: sim | threads[:N]",
                )
                .parse_from(rest)?;
            let id = a
                .positionals()
                .first()
                .cloned()
                .unwrap_or_else(|| "all".to_string());
            let backend = BackendKind::parse(&a.get("backend"))?;
            cmd_experiment(&id, a.get_bool("full"), backend)
        }
        "data" => {
            let a = Args::new("pgpr data", "generate datasets")
                .flag("dataset", "aimpeak", "sarcos | aimpeak | emslp")
                .flag("train", "1000", "training rows")
                .flag("test", "200", "test rows")
                .flag("seed", "0", "seed")
                .flag("out", "results/data", "output directory")
                .parse_from(rest)?;
            cmd_data_gen(
                &a.get("dataset"),
                a.get_usize("train"),
                a.get_usize("test"),
                a.get_usize("seed") as u64,
                &a.get("out"),
            )
        }
        "eval" => {
            let a = Args::new("pgpr eval", "fit + evaluate LMA on CSV data")
                .required("train-csv", "training data (x0..xd-1, y header)")
                .required("test-csv", "test data (same schema)")
                .flag("blocks", "8", "M — number of blocks")
                .flag("order", "1", "B — Markov order")
                .flag("support", "128", "|S| — support set size")
                .flag("seed", "0", "seed")
                .flag("out", "results/eval_predictions.csv", "prediction output CSV")
                .parse_from(rest)?;
            cmd_eval(
                &a.get("train-csv"),
                &a.get("test-csv"),
                a.get_usize("blocks"),
                a.get_usize("order"),
                a.get_usize("support"),
                a.get_usize("seed") as u64,
                &a.get("out"),
            )
        }
        "serve" => {
            let a = Args::new("pgpr serve", "batched prediction service (HTTP or stdin)")
                .flag("dataset", "aimpeak", "sarcos | aimpeak | emslp")
                .flag("train", "1000", "training rows")
                .flag("batch", "16", "micro-batch size in rows")
                .flag("seed", "0", "seed")
                .flag(
                    "backend",
                    "centralized",
                    "prediction engine: centralized | sim | threads[:N]",
                )
                .flag(
                    "listen",
                    "",
                    "HTTP listen address, e.g. 127.0.0.1:8080 (port 0 = ephemeral); empty = stdin line protocol",
                )
                .flag("workers", "4", "HTTP connection worker threads")
                .flag(
                    "max-delay-us",
                    "2000",
                    "partial-batch flush deadline in microseconds; 0 = no batching delay. \
                     In stdin mode expiry is only checked when the next input line arrives",
                )
                .flag("queue", "1024", "bounded request queue capacity (full ⇒ 503)")
                .parse_from(rest)?;
            let opts = ServeOptions {
                listen: a.get("listen"),
                workers: a.get_usize("workers"),
                batch_size: a.get_usize("batch"),
                max_delay_us: a.get_usize("max-delay-us") as u64,
                queue_capacity: a.get_usize("queue"),
            };
            cmd_serve(&ServeCmd {
                dataset: a.get("dataset"),
                train: a.get_usize("train"),
                seed: a.get_usize("seed") as u64,
                backend: a.get("backend"),
                opts,
            })
        }
        "loadtest" => {
            let a = Args::new("pgpr loadtest", "closed-loop load generator for the HTTP service")
                .flag(
                    "addr",
                    "",
                    "target host:port of a running `pgpr serve --listen`; empty = boot an in-process server",
                )
                .flag("dataset", "aimpeak", "self-mode dataset")
                .flag("train", "600", "self-mode training rows")
                .flag("seed", "0", "seed (model fit and query generation)")
                .flag(
                    "backend",
                    "threads:0",
                    "self-mode engine: centralized | sim | threads[:N]",
                )
                .flag("batch", "16", "self-mode micro-batch size")
                .flag("workers", "4", "self-mode HTTP worker threads")
                .flag("max-delay-us", "2000", "self-mode flush deadline (µs)")
                .flag("queue", "1024", "self-mode queue capacity")
                .flag("concurrency", "8", "closed-loop client threads")
                .flag("requests", "200", "total requests to send")
                .flag("rows", "1", "rows per request")
                .flag("out", "BENCH_serve_latency.json", "output record path")
                .parse_from(rest)?;
            cmd_loadtest(&LoadtestCmd {
                addr: a.get("addr"),
                dataset: a.get("dataset"),
                train: a.get_usize("train"),
                seed: a.get_usize("seed") as u64,
                backend: a.get("backend"),
                opts: ServeOptions {
                    listen: "127.0.0.1:0".into(),
                    workers: a.get_usize("workers"),
                    batch_size: a.get_usize("batch"),
                    max_delay_us: a.get_usize("max-delay-us") as u64,
                    queue_capacity: a.get_usize("queue"),
                },
                concurrency: a.get_usize("concurrency"),
                requests: a.get_usize("requests"),
                rows: a.get_usize("rows"),
                out: a.get("out"),
            })
        }
        "bench-info" => cmd_bench_info(),
        _ => {
            println!(
                "pgpr — Parallel GP Regression (LMA, AAAI 2015 reproduction)\n\n\
                 USAGE:\n  pgpr experiment <table1a|table1b|table2|table3|fig2|fig6|ablation|all> [--full] [--backend sim|threads[:N]]\n  \
                 pgpr data --dataset aimpeak --train 1000 --test 200 --out dir/\n  \
                 pgpr eval --train-csv train.csv --test-csv test.csv [--blocks 8 --order 1 --support 128]\n  \
                 pgpr serve --dataset aimpeak --train 1000 --batch 16 [--backend centralized|sim|threads[:N]]\n  \
                 \u{20}          [--listen 127.0.0.1:8080 --workers 4 --max-delay-us 2000 --queue 1024]\n  \
                 pgpr loadtest [--addr HOST:PORT | --dataset aimpeak --train 600 --backend threads:0]\n  \
                 \u{20}          [--concurrency 8 --requests 200 --rows 1 --out BENCH_serve_latency.json]\n  \
                 pgpr bench-info\n"
            );
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_gen_roundtrip() {
        let dir = std::env::temp_dir().join("pgpr_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        cmd_data_gen("aimpeak", 50, 10, 1, dir.to_str().unwrap()).unwrap();
        let (x, y) = load_xy_csv(dir.join("aimpeak-sim_train.csv").to_str().unwrap()).unwrap();
        assert_eq!(x.rows(), 50);
        assert_eq!(x.cols(), 5);
        assert_eq!(y.len(), 50);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn unknown_experiment_rejected() {
        assert!(cmd_experiment("bogus", false, BackendKind::Sim).is_err());
    }
}
