//! Layer-3 coordinator: the `pgpr` binary's subcommands and the batched
//! prediction service loop (fronted over the network by `crate::server`).
//!
//! Subcommands:
//! * `pgpr experiment <table1a|table1b|table2|table3|fig2|fig6|ablation|all> [--full]`
//! * `pgpr data gen --dataset <sarcos|aimpeak|emslp> --train N --test N --out dir/`
//! * `pgpr eval --train-csv ... --test-csv ...`
//! * `pgpr fit --dataset ... --save model.pgpr` — fit once, snapshot the
//!   engine to a versioned artifact (`registry::artifact`)
//! * `pgpr serve --dataset ... [--model name=path ...] [--batch N]
//!   [--listen host:port --workers N --max-delay-us D]` — HTTP service when
//!   `--listen` is set (multi-model registry when `--model` artifacts are
//!   given), stdin line protocol otherwise
//! * `pgpr observe --addr host:port --csv stream.csv [--model name]` —
//!   replay a CSV observation stream into a served model over
//!   `POST /models/<name>/observe` (incremental per-block updates,
//!   atomic generation swaps)
//! * `pgpr loadtest [--addr host:port | self-contained flags]
//!   [--model NAME ...] [--artifact name=path ...] [--rate R]` —
//!   closed-loop load generator (keep-alive and close modes) plus an
//!   optional open-loop coordinated-omission-corrected pass, writes
//!   `BENCH_serve_latency.json`
//! * `pgpr bench-info` — print artifact/bucket status

pub mod service;
pub mod cli_run;
