//! Layer-3 coordinator: the `pgpr` binary's subcommands, the model
//! registry and the batched prediction service loop.
//!
//! Subcommands:
//! * `pgpr experiment <table1a|table1b|table2|table3|fig2|fig6|ablation|all> [--full]`
//! * `pgpr data gen --dataset <sarcos|aimpeak|emslp> --train N --test N --out dir/`
//! * `pgpr train --dataset ... | --train-csv ... --model out.json`
//! * `pgpr serve --dataset ... [--batch N]` — line protocol on stdin
//! * `pgpr bench-info` — print artifact/bucket status

pub mod service;
pub mod cli_run;
