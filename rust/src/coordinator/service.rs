//! Batched prediction service.
//!
//! The serving loop accepts single-point prediction requests, accumulates
//! them into batches (up to `batch_size` or until `flush` is called) and
//! answers them with one LMA predict call per batch — amortizing the
//! sweep/summary cost exactly like a serving system batches GPU calls.
//! This is the request path a downstream user would deploy; Python is
//! never involved.
//!
//! Two front ends drive it: the `pgpr serve` stdin line protocol (this
//! module used directly) and the HTTP server (`server::http`), where a
//! dedicated batcher thread (`server::batcher`) owns the service and uses
//! [`PredictionService::deadline`] / [`PredictionService::flush_expired`]
//! so a partial batch is answered within `max_delay` instead of waiting
//! for `batch_size` forever. Latency/occupancy statistics go to a shared
//! [`ServeMetrics`] (atomic histograms) exposing p50/p95/p99.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::gp::Prediction;
use crate::linalg::matrix::Mat;
use crate::lma::context::PredictScratch;
use crate::lma::f32u::PredictMode;
use crate::lma::parallel::ParallelLma;
use crate::lma::residual::LmaFitCore;
use crate::lma::LmaRegressor;
use crate::obs::{Stage, StageSet};
use crate::server::metrics::ServeMetrics;
use crate::util::error::{PgprError, Result};
use crate::util::timer::{time_it, PhaseProfiler};

/// Which prediction engine answers batches: the single-process
/// centralized regressor, or the parallel engine on a cluster backend
/// (virtual-time sim or real threads, per its `ClusterConfig::backend`).
///
/// All fitted state is immutable after construction, so the engine is
/// `Send + Sync` and can be shared across serving threads behind an
/// `Arc` (asserted at compile time below).
pub enum ServeEngine {
    Centralized(LmaRegressor),
    Parallel(ParallelLma),
}

impl ServeEngine {
    pub fn core(&self) -> &LmaFitCore {
        match self {
            ServeEngine::Centralized(m) => m.core(),
            ServeEngine::Parallel(m) => m.core(),
        }
    }

    /// Stamp the fit-time quality baseline (held-out RMSE/MNLP) into the
    /// fitted core, where artifact serialization persists it and the
    /// online-update path carries it across generations — the reference
    /// every windowed `drift_score` is measured against.
    pub fn set_quality_baseline(&mut self, baseline: crate::obs::quality::QualityBaseline) {
        let core = match self {
            ServeEngine::Centralized(m) => m.core_mut(),
            ServeEngine::Parallel(m) => m.core_mut(),
        };
        core.quality_baseline = Some(baseline);
    }

    pub fn predict(&self, x: &Mat) -> Result<Prediction> {
        match self {
            ServeEngine::Centralized(m) => m.predict(x),
            ServeEngine::Parallel(m) => m.predict(x).map(|r| r.prediction),
        }
    }

    /// Predict reusing a caller-owned scratch workspace. The centralized
    /// engine recycles its per-call buffers through it (near-zero heap
    /// traffic in steady state); the cluster engines manage their own
    /// per-rank state, so the scratch is unused there.
    pub fn predict_with_scratch(
        &self,
        x: &Mat,
        scratch: &mut PredictScratch,
    ) -> Result<Prediction> {
        match self {
            ServeEngine::Centralized(m) => m.predict_with_scratch(x, scratch),
            ServeEngine::Parallel(m) => m.predict(x).map(|r| r.prediction),
        }
    }

    /// [`predict_with_scratch`](Self::predict_with_scratch) in an explicit
    /// [`PredictMode`]. Parallel engines have no f32 context — they serve
    /// the exact f64 path regardless of the requested mode (documented
    /// fallback; the CLI warns when `--f32-u` meets a cluster backend).
    pub fn predict_with_mode(
        &self,
        x: &Mat,
        mode: PredictMode,
        scratch: &mut PredictScratch,
    ) -> Result<Prediction> {
        match self {
            ServeEngine::Centralized(m) => m.predict_with_mode(x, mode, scratch),
            ServeEngine::Parallel(m) => m.predict(x).map(|r| r.prediction),
        }
    }

    /// [`predict_with_mode`](Self::predict_with_mode), also returning the
    /// call's phase profile — the serving layer's per-stage attribution
    /// source (centralized engines report their real predict phases;
    /// parallel engines charge the whole protocol to `predict/parallel`).
    pub fn predict_traced(
        &self,
        x: &Mat,
        mode: PredictMode,
        scratch: &mut PredictScratch,
    ) -> Result<(Prediction, PhaseProfiler)> {
        match self {
            ServeEngine::Centralized(m) => m.predict_traced(x, mode, scratch),
            ServeEngine::Parallel(m) => m.predict_traced(x),
        }
    }

    /// Fit-time phase profile, when the engine keeps one (centralized
    /// engines; cluster engines charge fit to per-rank accounting, so
    /// they have no phase taxonomy to report).
    pub fn fit_profiler(&self) -> Option<&PhaseProfiler> {
        match self {
            ServeEngine::Centralized(m) => Some(m.profiler()),
            ServeEngine::Parallel(_) => None,
        }
    }

    /// Human-readable engine selector (mirrors the `--backend` flag).
    pub fn backend_name(&self) -> String {
        match self {
            ServeEngine::Centralized(_) => "centralized".to_string(),
            ServeEngine::Parallel(m) => m.cluster_config().backend.selector(),
        }
    }

    /// Rebuild the same engine kind around an updated fitted core — how
    /// an online update publishes a new generation. Parallel engines keep
    /// their backend/latency model but the topology tracks the (possibly
    /// grown) block count: the core count stays one-block-per-core.
    pub fn with_core(&self, core: LmaFitCore) -> Result<ServeEngine> {
        match self {
            ServeEngine::Centralized(_) => {
                Ok(ServeEngine::Centralized(LmaRegressor::from_core(core)))
            }
            ServeEngine::Parallel(m) => {
                let mut cc = m.cluster_config().clone();
                let mm = core.m();
                if cc.total_cores() != mm {
                    // One block per core must keep holding. Keep as many
                    // machines as divide the new M (largest divisor ≤ the
                    // current count); an indivisible M falls back to fewer
                    // machines. The fallback is sticky — the config does
                    // not remember the boot machine count — which only
                    // affects the simulator's latency/traffic model,
                    // never predictions. (The serving CLI always builds
                    // single-machine topologies, where this is exact.)
                    let machines = (1..=cc.machines.max(1))
                        .rev()
                        .find(|w| mm % w == 0)
                        .unwrap_or(1);
                    cc.machines = machines;
                    cc.cores_per_machine = mm / machines;
                }
                Ok(ServeEngine::Parallel(ParallelLma::from_parts(core, cc)?))
            }
        }
    }

    /// Worker-pool width for the independent per-block work of an online
    /// update: the cluster backend's real parallelism for parallel
    /// engines (new blocks are fitted on their owning rank's workers),
    /// the global `util::par` count for centralized ones.
    pub fn update_parallelism(&self) -> usize {
        match self {
            ServeEngine::Centralized(_) => crate::util::par::num_threads(),
            ServeEngine::Parallel(m) => m.cluster_config().backend.parallelism(),
        }
    }
}

// The serving threads share one engine behind `Arc`; keep that possible.
#[allow(dead_code)]
fn _assert_engine_send_sync() {
    fn check<T: Send + Sync>() {}
    check::<ServeEngine>();
    check::<Arc<ServeEngine>>();
}

/// One pending request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub x: Vec<f64>,
}

/// One answered request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub mean: f64,
    pub var: f64,
    /// Wall-clock seconds between enqueue and answer batch completion.
    pub latency: f64,
    /// Stage breakdown of the batch that answered this request (engine
    /// phases are shared batch-wide; per-request queue/batch-form stages
    /// are layered on by the batcher). Zeroed when tracing is off.
    pub stages: StageSet,
    /// 1-based sequence number of the answering batch — lets a caller
    /// holding several responses merge engine stages once per batch
    /// (0 = tracing off).
    pub batch: u64,
    /// Seconds this request waited after service enqueue for its batch
    /// to fill or expire (0 when tracing is off).
    pub batch_form_s: f64,
}

/// Batching predictor over a fitted LMA engine. The engine is held
/// behind an `Arc` so the same fitted state can simultaneously live in
/// the model registry, in this service (on the batcher thread) and in
/// any in-flight eviction — all without copying the fitted matrices.
pub struct PredictionService {
    engine: Arc<ServeEngine>,
    batch_size: usize,
    /// Deadline for partial batches: the oldest queued request is
    /// answered within this duration even if the batch never fills
    /// (`None` = legacy wait-for-full-batch behavior).
    max_delay: Option<Duration>,
    queue: Vec<(Request, Instant)>,
    /// Shared latency/occupancy histograms (p50/p95/p99 via
    /// `server::metrics`); `Arc` so the HTTP layer renders the same
    /// object the service records into.
    metrics: Arc<ServeMetrics>,
    /// Reusable predict workspace — this service is owned by one thread
    /// (the batcher / stdin loop), so steady-state batches recycle the
    /// per-call buffers instead of reallocating them.
    scratch: PredictScratch,
    /// Arithmetic mode batches are answered in (`--f32-u` opts into
    /// [`PredictMode::F32U`]; default is the exact f64 path).
    mode: PredictMode,
    /// Per-stage attribution: when on, batches run the traced engine path
    /// and every [`Response`] carries its stage breakdown (default on —
    /// the bench asserts the recorder's p50 cost stays under 5%).
    trace: bool,
    /// 1-based counter of flushed batches, stamped into [`Response::batch`].
    batch_seq: u64,
    /// Serving statistics (kept as plain fields for back-compat).
    pub served: usize,
    pub batches: usize,
    pub total_latency: f64,
    pub predict_secs: f64,
}

impl PredictionService {
    /// Serve a centralized regressor (back-compat constructor).
    pub fn new(model: LmaRegressor, batch_size: usize) -> Result<PredictionService> {
        Self::with_engine(ServeEngine::Centralized(model), batch_size)
    }

    /// Serve any engine (centralized, or parallel on a sim/thread
    /// cluster backend).
    pub fn with_engine(engine: ServeEngine, batch_size: usize) -> Result<PredictionService> {
        Self::with_shared(Arc::new(engine), batch_size)
    }

    /// Serve an engine that is shared with other owners (the model
    /// registry hands every batcher an `Arc` of its entry's engine).
    pub fn with_shared(engine: Arc<ServeEngine>, batch_size: usize) -> Result<PredictionService> {
        Self::with_shared_metrics(engine, batch_size, Arc::new(ServeMetrics::new()))
    }

    /// [`with_shared`](Self::with_shared) recording into a caller-owned
    /// metrics object — the registry passes the *previous* generation's
    /// metrics when an online update swaps engines, so per-model
    /// histograms and counters persist across generations.
    pub fn with_shared_metrics(
        engine: Arc<ServeEngine>,
        batch_size: usize,
        metrics: Arc<ServeMetrics>,
    ) -> Result<PredictionService> {
        if batch_size == 0 {
            return Err(PgprError::Config("batch_size must be ≥ 1".into()));
        }
        Ok(PredictionService {
            engine,
            batch_size,
            max_delay: None,
            queue: Vec::new(),
            metrics,
            scratch: PredictScratch::new(),
            mode: PredictMode::F64,
            trace: true,
            batch_seq: 0,
            served: 0,
            batches: 0,
            total_latency: 0.0,
            predict_secs: 0.0,
        })
    }

    /// Builder-style partial-batch deadline: the oldest queued request is
    /// flushed within `d` (via [`deadline`](Self::deadline) +
    /// [`flush_expired`](Self::flush_expired), driven by the caller's
    /// loop — the HTTP batcher thread, or the stdin loop between lines).
    pub fn with_max_delay(mut self, d: Duration) -> PredictionService {
        self.max_delay = Some(d);
        self
    }

    pub fn max_delay(&self) -> Option<Duration> {
        self.max_delay
    }

    /// The flush threshold (rows per micro-batch). The batcher supervisor
    /// reads this to rebuild an identically-configured service after a
    /// panic.
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Builder-style predict mode (`--f32-u` passes
    /// [`PredictMode::F32U`]).
    pub fn with_predict_mode(mut self, mode: PredictMode) -> PredictionService {
        self.mode = mode;
        self
    }

    pub fn predict_mode(&self) -> PredictMode {
        self.mode
    }

    /// Builder-style tracing switch (`--no-trace` turns the per-stage
    /// recorder off for overhead measurement).
    pub fn with_trace(mut self, trace: bool) -> PredictionService {
        self.trace = trace;
        self
    }

    pub fn trace(&self) -> bool {
        self.trace
    }

    /// Shared metrics handle (same object the service records into).
    pub fn metrics(&self) -> Arc<ServeMetrics> {
        Arc::clone(&self.metrics)
    }

    pub fn engine(&self) -> &ServeEngine {
        &self.engine
    }

    /// Shared handle to the engine this service answers with.
    pub fn shared_engine(&self) -> Arc<ServeEngine> {
        Arc::clone(&self.engine)
    }

    pub fn dim(&self) -> usize {
        self.engine.core().hyp.dim()
    }

    /// Rows currently waiting for a batch.
    pub fn queued_rows(&self) -> usize {
        self.queue.len()
    }

    /// When the oldest queued request must be answered, if a deadline is
    /// configured and anything is queued.
    pub fn deadline(&self) -> Option<Instant> {
        match (self.max_delay, self.queue.first()) {
            (Some(d), Some((_, t0))) => Some(*t0 + d),
            _ => None,
        }
    }

    /// Flush iff the oldest queued request's deadline has expired. This is
    /// the fix for the stranded-tail-request bug: callers with a
    /// `max_delay` poll this (or sleep until [`deadline`](Self::deadline))
    /// so a partial batch is answered within `max_delay` instead of
    /// waiting for `batch_size` forever.
    pub fn flush_expired(&mut self) -> Result<Vec<Response>> {
        match self.deadline() {
            Some(dl) if Instant::now() >= dl => self.flush(),
            _ => Ok(Vec::new()),
        }
    }

    /// Enqueue a request; answers the whole batch when full.
    pub fn submit(&mut self, req: Request) -> Result<Vec<Response>> {
        if req.x.len() != self.dim() {
            return Err(PgprError::Shape(format!(
                "request {} has dim {}, model expects {}",
                req.id,
                req.x.len(),
                self.dim()
            )));
        }
        self.queue.push((req, Instant::now()));
        self.metrics.requests.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if self.queue.len() >= self.batch_size {
            self.flush()
        } else {
            Ok(Vec::new())
        }
    }

    /// Answer everything queued.
    pub fn flush(&mut self) -> Result<Vec<Response>> {
        if self.queue.is_empty() {
            return Ok(Vec::new());
        }
        let flush_start = Instant::now();
        let batch: Vec<(Request, Instant)> = std::mem::take(&mut self.queue);
        let mut x = Mat::zeros(batch.len(), self.dim());
        for (i, (req, _)) in batch.iter().enumerate() {
            x.row_mut(i).copy_from_slice(&req.x);
        }
        let engine = Arc::clone(&self.engine);
        // Traced batches run the profiled engine path and convert its
        // phase totals into stage times; any engine wall-clock the
        // profiler didn't attribute (scatter, phase edges) folds into
        // `engine_other` so a request's stage sum tracks its latency.
        let mut stages = StageSet::new();
        let (pred, secs) = if self.trace {
            let (res, secs) = time_it(|| {
                // Chaos hook: an armed `engine_stall_ms` slows every
                // predict, counted inside `predict_us` so the admission
                // gate's queue-delay estimate sees the degradation.
                crate::util::fault::stall(crate::util::fault::ENGINE_STALL_MS);
                engine.predict_traced(&x, self.mode, &mut self.scratch)
            });
            let (pred, prof) = res?;
            stages = StageSet::from_profiler(&prof);
            let gap = secs - stages.sum();
            if gap > 0.0 {
                stages.add(Stage::EngineOther, gap);
            }
            self.metrics.stages.record_set(&stages);
            (pred, secs)
        } else {
            let (res, secs) = time_it(|| {
                crate::util::fault::stall(crate::util::fault::ENGINE_STALL_MS);
                engine.predict_with_mode(&x, self.mode, &mut self.scratch)
            });
            (res?, secs)
        };
        self.predict_secs += secs;
        self.batches += 1;
        self.batch_seq += 1;
        self.metrics.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.metrics.batch_rows.record(batch.len() as u64);
        self.metrics.predict_us.record((secs * 1e6) as u64);
        let batch_seq = if self.trace { self.batch_seq } else { 0 };
        let mut out = Vec::with_capacity(batch.len());
        for (i, (req, t0)) in batch.into_iter().enumerate() {
            let latency = t0.elapsed().as_secs_f64();
            self.total_latency += latency;
            self.served += 1;
            self.metrics.latency_us.record((latency * 1e6) as u64);
            self.metrics.responses.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let batch_form_s = if self.trace {
                let wait = flush_start.saturating_duration_since(t0).as_secs_f64();
                self.metrics.stages.record(Stage::BatchForm, wait);
                wait
            } else {
                0.0
            };
            out.push(Response {
                id: req.id,
                mean: pred.mean[i],
                var: pred.var[i],
                latency,
                stages,
                batch: batch_seq,
                batch_form_s,
            });
        }
        Ok(out)
    }

    /// Mean latency over everything served so far.
    pub fn mean_latency(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.total_latency / self.served as f64
        }
    }

    /// (p50, p95, p99) request latency in seconds, from the shared
    /// histogram.
    pub fn latency_quantiles(&self) -> (f64, f64, f64) {
        let h = &self.metrics.latency_us;
        (
            h.quantile(0.5) as f64 * 1e-6,
            h.quantile(0.95) as f64 * 1e-6,
            h.quantile(0.99) as f64 * 1e-6,
        )
    }

    /// Throughput over pure predict time.
    pub fn throughput(&self) -> f64 {
        if self.predict_secs <= 0.0 {
            0.0
        } else {
            self.served as f64 / self.predict_secs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LmaConfig, PartitionStrategy};
    use crate::kernels::se_ard::SeArdHyper;
    use crate::util::rng::Pcg64;

    fn service(batch: usize) -> PredictionService {
        let mut rng = Pcg64::new(241);
        let hyp = SeArdHyper::isotropic(1, 1.0, 1.0, 0.1);
        let x = Mat::col_vec(&rng.uniform_vec(150, -4.0, 4.0));
        let y: Vec<f64> = (0..150).map(|i| x.get(i, 0).sin()).collect();
        let cfg = LmaConfig {
            num_blocks: 5,
            markov_order: 1,
            support_size: 24,
            seed: 1,
            partition: PartitionStrategy::KMeans { iters: 6 },
            use_pjrt: false,
        };
        let model = LmaRegressor::fit(&x, &y, &hyp, &cfg).unwrap();
        PredictionService::new(model, batch).unwrap()
    }

    #[test]
    fn batches_fire_at_capacity() {
        let mut s = service(3);
        assert!(s.submit(Request { id: 1, x: vec![0.5] }).unwrap().is_empty());
        assert!(s.submit(Request { id: 2, x: vec![1.0] }).unwrap().is_empty());
        let out = s.submit(Request { id: 3, x: vec![-1.0] }).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].id, 1);
        assert!(s.served == 3 && s.batches == 1);
        // Answers match the function being regressed.
        assert!((out[0].mean - 0.5f64.sin()).abs() < 0.2);
    }

    #[test]
    fn flush_drains_partial_batch() {
        let mut s = service(10);
        s.submit(Request { id: 7, x: vec![0.0] }).unwrap();
        let out = s.flush().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 7);
        assert!(s.flush().unwrap().is_empty());
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let mut s = service(100).with_max_delay(Duration::from_millis(200));
        // Nothing queued: no deadline, nothing to flush.
        assert!(s.deadline().is_none());
        assert!(s.flush_expired().unwrap().is_empty());
        s.submit(Request { id: 1, x: vec![0.3] }).unwrap();
        let dl = s.deadline().expect("deadline once queued");
        // Well before the 200ms deadline: still queued.
        assert!(s.flush_expired().unwrap().is_empty());
        assert_eq!(s.queued_rows(), 1);
        std::thread::sleep(Duration::from_millis(220));
        assert!(Instant::now() >= dl);
        // After the deadline: the lone request is answered.
        let out = s.flush_expired().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 1);
        assert_eq!(s.queued_rows(), 0);
        assert!(s.deadline().is_none());
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let mut s = service(2);
        assert!(s.submit(Request { id: 1, x: vec![0.0, 1.0] }).is_err());
    }

    #[test]
    fn parallel_thread_engine_serves_batches() {
        use crate::config::{BackendKind, ClusterConfig};
        let mut rng = Pcg64::new(242);
        let hyp = SeArdHyper::isotropic(1, 1.0, 1.0, 0.1);
        let x = Mat::col_vec(&rng.uniform_vec(120, -4.0, 4.0));
        let y: Vec<f64> = (0..120).map(|i| x.get(i, 0).sin()).collect();
        let cfg = LmaConfig {
            num_blocks: 4,
            markov_order: 1,
            support_size: 20,
            seed: 1,
            partition: PartitionStrategy::KMeans { iters: 6 },
            use_pjrt: false,
        };
        let cc = ClusterConfig::gigabit(1, 4)
            .with_backend(BackendKind::Threads { num_threads: 2 });
        let model = ParallelLma::fit(&x, &y, &hyp, &cfg, &cc).unwrap();
        let mut s =
            PredictionService::with_engine(ServeEngine::Parallel(model), 2).unwrap();
        assert_eq!(s.dim(), 1);
        assert_eq!(s.engine().backend_name(), "threads:2");
        assert!(s.submit(Request { id: 1, x: vec![0.5] }).unwrap().is_empty());
        let out = s.submit(Request { id: 2, x: vec![1.0] }).unwrap();
        assert_eq!(out.len(), 2);
        assert!((out[0].mean - 0.5f64.sin()).abs() < 0.3);
    }

    #[test]
    fn f32u_mode_serves_within_mean_budget() {
        // Same deterministic model, served in both modes: the reduced-
        // precision answers stay within the 1e-5 relative mean budget.
        let mut exact = service(2);
        let mut reduced = service(2).with_predict_mode(PredictMode::F32U);
        assert_eq!(exact.predict_mode(), PredictMode::F64);
        assert_eq!(reduced.predict_mode(), PredictMode::F32U);
        let xs = [0.4, -1.2, 2.1, -0.3];
        let mut a = Vec::new();
        let mut b = Vec::new();
        for (i, &x) in xs.iter().enumerate() {
            a.extend(exact.submit(Request { id: i as u64, x: vec![x] }).unwrap());
            b.extend(reduced.submit(Request { id: i as u64, x: vec![x] }).unwrap());
        }
        assert_eq!(a.len(), 4);
        assert_eq!(b.len(), 4);
        for (e, r) in a.iter().zip(&b) {
            assert!((e.mean - r.mean).abs() < 1e-5, "{} vs {}", e.mean, r.mean);
            assert!((e.var - r.var).abs() < 1e-4);
        }
    }

    #[test]
    fn traced_batches_carry_stage_breakdowns() {
        let mut s = service(2);
        assert!(s.trace());
        s.submit(Request { id: 1, x: vec![0.2] }).unwrap();
        let out = s.submit(Request { id: 2, x: vec![0.9] }).unwrap();
        assert_eq!(out.len(), 2);
        for r in &out {
            assert_eq!(r.batch, 1, "first flushed batch");
            // Engine phases were recorded and cover most of the latency
            // (queue-wait is the batcher's layer, absent here).
            let engine_s: f64 = r.stages.sum();
            assert!(engine_s > 0.0);
            assert!(
                engine_s + r.batch_form_s <= r.latency * 1.5 + 1e-3,
                "stage sum {engine_s} vs latency {}",
                r.latency
            );
        }
        // Second batch gets the next sequence number.
        s.submit(Request { id: 3, x: vec![-0.4] }).unwrap();
        let out2 = s.submit(Request { id: 4, x: vec![1.4] }).unwrap();
        assert_eq!(out2[0].batch, 2);
        // The shared metrics saw the engine stages + batch formation.
        let m = s.metrics();
        assert!(m.stages.get(crate::obs::Stage::SweepRbarDu).count() >= 1);
        assert_eq!(m.stages.get(crate::obs::Stage::BatchForm).count(), 4);
        // Tracing off: no stage work, sentinel batch 0.
        let mut off = service(1).with_trace(false);
        let out3 = off.submit(Request { id: 9, x: vec![0.1] }).unwrap();
        assert_eq!(out3[0].batch, 0);
        assert_eq!(out3[0].stages.sum(), 0.0);
        assert_eq!(off.metrics().stages.get(crate::obs::Stage::BatchForm).count(), 0);
    }

    #[test]
    fn stats_accumulate() {
        let mut s = service(2);
        for i in 0..6 {
            s.submit(Request { id: i, x: vec![i as f64 * 0.3] }).unwrap();
        }
        assert_eq!(s.served, 6);
        assert_eq!(s.batches, 3);
        assert!(s.throughput() > 0.0);
        assert!(s.mean_latency() >= 0.0);
        // The shared histogram saw the same traffic.
        let m = s.metrics();
        assert_eq!(m.responses.load(std::sync::atomic::Ordering::Relaxed), 6);
        assert_eq!(m.batches.load(std::sync::atomic::Ordering::Relaxed), 3);
        assert_eq!(m.batch_rows.quantile(0.5), 2);
        let (p50, p95, p99) = s.latency_quantiles();
        assert!(p50 <= p95 && p95 <= p99);
        assert!(p99 > 0.0);
    }
}
