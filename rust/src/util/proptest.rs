//! Tiny property-based testing helper (offline replacement for `proptest`).
//!
//! `for_cases(seed, n, |rng| ...)` runs a property closure over `n`
//! independently seeded cases and reports the failing case index + seed on
//! panic, so failures are reproducible: re-run with `PGPR_PROP_SEED=<seed>`
//! and `PGPR_PROP_CASE=<idx>` to isolate one case.
//!
//! Coordinator invariants (partition routing, summary order-invariance,
//! banded structure, PSD-ness of predictive covariances, ...) are tested
//! through this helper — see `rust/tests/prop_*.rs`.

use crate::util::rng::Pcg64;

/// Number of cases to run, scaled down when `PGPR_PROP_FAST` is set.
pub fn default_cases(n: usize) -> usize {
    if std::env::var("PGPR_PROP_FAST").is_ok() {
        (n / 4).max(4)
    } else {
        n
    }
}

/// Run `prop` on `n` cases, each with its own deterministic RNG stream.
pub fn for_cases(seed: u64, n: usize, mut prop: impl FnMut(&mut Pcg64)) {
    let seed = std::env::var("PGPR_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(seed);
    let only_case: Option<usize> =
        std::env::var("PGPR_PROP_CASE").ok().and_then(|s| s.parse().ok());
    let mut root = Pcg64::new(seed);
    for case in 0..default_cases(n) {
        let mut rng = root.split(case as u64);
        if let Some(oc) = only_case {
            if case != oc {
                continue;
            }
        }
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            eprintln!(
                "property failed at case {case} (reproduce with PGPR_PROP_SEED={seed} PGPR_PROP_CASE={case})"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

// ----- common generators -----

/// Random size in [lo, hi].
pub fn gen_size(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

/// Vector of standard normals scaled by `scale`.
pub fn gen_vec(rng: &mut Pcg64, n: usize, scale: f64) -> Vec<f64> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

/// Random symmetric positive-definite matrix (as flat row-major data) of
/// size n, built as A Aᵀ + n·εI. Returned as (data, n).
pub fn gen_spd(rng: &mut Pcg64, n: usize) -> Vec<f64> {
    let a: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
    let mut m = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0;
            for k in 0..n {
                acc += a[i * n + k] * a[j * n + k];
            }
            m[i * n + j] = acc;
        }
    }
    for i in 0..n {
        m[i * n + i] += 1e-6 * n as f64 + 1e-3;
    }
    m
}

/// Assert two slices are elementwise close.
#[track_caller]
pub fn assert_close(a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = 1.0_f64.max(x.abs()).max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "mismatch at {i}: {x} vs {y} (tol {tol}, scale {scale})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        for_cases(1, 8, |_rng| {
            count += 1;
        });
        assert!(count >= 4);
    }

    #[test]
    fn generators_in_bounds() {
        for_cases(2, 8, |rng| {
            let n = gen_size(rng, 3, 10);
            assert!((3..=10).contains(&n));
            let v = gen_vec(rng, n, 2.0);
            assert_eq!(v.len(), n);
        });
    }

    #[test]
    fn spd_is_symmetric_with_positive_diagonal() {
        for_cases(3, 6, |rng| {
            let n = gen_size(rng, 2, 8);
            let m = gen_spd(rng, n);
            for i in 0..n {
                assert!(m[i * n + i] > 0.0);
                for j in 0..n {
                    assert!((m[i * n + j] - m[j * n + i]).abs() < 1e-12);
                }
            }
        });
    }

    #[test]
    #[should_panic]
    fn assert_close_catches_mismatch() {
        assert_close(&[1.0], &[1.1], 1e-6);
    }
}
