//! Wall-clock timing helpers and a hierarchical phase profiler.
//!
//! The phase profiler is how the coordinator attributes end-to-end time to
//! partitioning / covariance / Cholesky / summary / communication segments —
//! it backs both the experiment tables (incurred-time columns) and the §Perf
//! analysis in EXPERIMENTS.md.

use std::collections::BTreeMap;
use std::time::Instant;

/// Measure one closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Accumulates named phase durations. Cheap enough to leave on in
/// production paths (one Instant per phase edge).
#[derive(Debug, Default, Clone)]
pub struct PhaseProfiler {
    totals: BTreeMap<String, f64>,
    counts: BTreeMap<String, u64>,
}

impl PhaseProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f` attributed to `phase`.
    pub fn scope<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let (out, secs) = time_it(f);
        self.add(phase, secs);
        out
    }

    /// Manually add seconds to a phase (used when the duration comes from
    /// the cluster simulator's virtual clock rather than real time).
    pub fn add(&mut self, phase: &str, secs: f64) {
        *self.totals.entry(phase.to_string()).or_insert(0.0) += secs;
        *self.counts.entry(phase.to_string()).or_insert(0) += 1;
    }

    /// Merge another profiler into this one.
    pub fn merge(&mut self, other: &PhaseProfiler) {
        for (k, v) in &other.totals {
            *self.totals.entry(k.clone()).or_insert(0.0) += v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k.clone()).or_insert(0) += v;
        }
    }

    pub fn total(&self, phase: &str) -> f64 {
        self.totals.get(phase).copied().unwrap_or(0.0)
    }

    /// Iterate `(phase, total_secs)` in name order (the serving layer's
    /// stage mapping and the registry's fit-phase export both walk this).
    pub fn phases(&self) -> impl Iterator<Item = (&str, f64)> {
        self.totals.iter().map(|(k, &v)| (k.as_str(), v))
    }

    pub fn grand_total(&self) -> f64 {
        self.totals.values().sum()
    }

    /// Phases sorted by descending share of total time.
    pub fn breakdown(&self) -> Vec<(String, f64, f64)> {
        let total = self.grand_total().max(1e-300);
        let mut rows: Vec<(String, f64, f64)> = self
            .totals
            .iter()
            .map(|(k, &v)| (k.clone(), v, v / total))
            .collect();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        rows
    }

    /// Human-readable report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for (name, secs, frac) in self.breakdown() {
            let n = self.counts.get(&name).copied().unwrap_or(0);
            s.push_str(&format!(
                "  {name:<28} {secs:>10.4}s  {:>5.1}%  (n={n})\n",
                frac * 100.0
            ));
        }
        s
    }
}

/// Format seconds like the paper's tables (integer seconds for large,
/// sub-second precision for small).
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 1.0 {
        format!("{s:.1}")
    } else {
        format!("{s:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_measures() {
        let (v, secs) = time_it(|| {
            let mut acc = 0u64;
            for i in 0..100_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(v > 0);
        assert!(secs >= 0.0);
    }

    #[test]
    fn profiler_accumulates_and_merges() {
        let mut p = PhaseProfiler::new();
        p.add("a", 1.0);
        p.add("a", 2.0);
        p.add("b", 0.5);
        let mut q = PhaseProfiler::new();
        q.add("b", 0.5);
        p.merge(&q);
        assert!((p.total("a") - 3.0).abs() < 1e-12);
        assert!((p.total("b") - 1.0).abs() < 1e-12);
        assert!((p.grand_total() - 4.0).abs() < 1e-12);
        let top = &p.breakdown()[0];
        assert_eq!(top.0, "a");
    }

    #[test]
    fn fmt_secs_bands() {
        assert_eq!(fmt_secs(123.4), "123");
        assert_eq!(fmt_secs(5.25), "5.2");
        assert_eq!(fmt_secs(0.1234), "0.123");
    }
}
