//! Library-wide error type.
//!
//! A single enum keeps matching on failure modes easy for callers (e.g. the
//! coordinator retries Cholesky failures with more jitter, and treats
//! artifact-not-found as "fall back to the native covariance path").

use std::fmt;

/// All errors produced by the pgpr library.
#[derive(Debug)]
pub enum PgprError {
    /// A matrix operation received incompatible dimensions.
    Shape(String),
    /// Cholesky factorization failed (matrix not positive definite even
    /// after jitter retries).
    NotPositiveDefinite { size: usize, jitter_tried: f64 },
    /// Configuration was invalid (bad flag value, inconsistent block/order
    /// combination, ...).
    Config(String),
    /// An AOT artifact was missing or malformed.
    Artifact(String),
    /// The PJRT runtime reported an error.
    Pjrt(String),
    /// Dataset generation / parsing failure.
    Data(String),
    /// I/O error with context.
    Io(String),
    /// Cluster-simulation protocol violation (e.g. message to unknown rank).
    Cluster(String),
}

impl fmt::Display for PgprError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PgprError::Shape(m) => write!(f, "shape error: {m}"),
            PgprError::NotPositiveDefinite { size, jitter_tried } => write!(
                f,
                "matrix of size {size} not positive definite (max jitter tried: {jitter_tried:e})"
            ),
            PgprError::Config(m) => write!(f, "config error: {m}"),
            PgprError::Artifact(m) => write!(f, "artifact error: {m}"),
            PgprError::Pjrt(m) => write!(f, "pjrt error: {m}"),
            PgprError::Data(m) => write!(f, "data error: {m}"),
            PgprError::Io(m) => write!(f, "io error: {m}"),
            PgprError::Cluster(m) => write!(f, "cluster error: {m}"),
        }
    }
}

impl std::error::Error for PgprError {}

impl From<std::io::Error> for PgprError {
    fn from(e: std::io::Error) -> Self {
        PgprError::Io(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, PgprError>;

/// Helper for constructing shape errors with uniform formatting.
pub fn shape_err<T>(msg: impl Into<String>) -> Result<T> {
    Err(PgprError::Shape(msg.into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = PgprError::NotPositiveDefinite { size: 8, jitter_tried: 1e-4 };
        let s = e.to_string();
        assert!(s.contains('8'));
        assert!(s.contains("positive definite"));
    }

    #[test]
    fn io_conversion() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: PgprError = ioe.into();
        assert!(matches!(e, PgprError::Io(_)));
    }

    #[test]
    fn shape_err_helper() {
        let r: Result<()> = shape_err("a x b");
        assert!(matches!(r, Err(PgprError::Shape(_))));
    }
}
