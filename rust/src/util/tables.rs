//! Paper-style table rendering.
//!
//! The experiment harnesses print their results in the same row/column
//! layout as the paper's Tables 1–3 (RMSE with incurred time in brackets),
//! so a reader can eyeball paper-vs-measured side by side.

/// A text table with a title, column headers and string cells.
#[derive(Clone, Debug, Default)]
pub struct TextTable {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(title: &str, header: &[&str]) -> Self {
        TextTable {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row arity mismatch");
        self.rows.push(cells);
    }

    /// Paper-style cell: `RMSE(time)` e.g. `2.4(285)`.
    pub fn rmse_time_cell(rmse: f64, secs: f64) -> String {
        let t = if secs >= 100.0 {
            format!("{secs:.0}")
        } else if secs >= 1.0 {
            format!("{secs:.1}")
        } else {
            format!("{secs:.2}")
        };
        format!("{rmse:.4}({t})")
    }

    /// Paper-style cell: `speedup(time)` e.g. `6.9(139)`.
    pub fn speedup_time_cell(speedup: f64, secs: f64) -> String {
        format!("{speedup:.1}({:.1})", secs)
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!(" {:<width$} ", cells[i], width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&format!("\n{}\n", self.title));
        out.push_str(&format!("{sep}\n"));
        out.push_str(&format!("{}\n", fmt_row(&self.header)));
        out.push_str(&format!("{sep}\n"));
        for row in &self.rows {
            out.push_str(&format!("{}\n", fmt_row(row)));
        }
        out.push_str(&format!("{sep}\n"));
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new("Table X", &["|D|", "LMA", "PIC"]);
        t.row(vec!["8000".into(), TextTable::rmse_time_cell(8.4, 20.0), "8.1(484)".into()]);
        t.row(vec!["16000".into(), "7.5(44)".into(), "7.5(536)".into()]);
        let s = t.render();
        assert!(s.contains("Table X"));
        assert!(s.contains("8.4000(20.0)"));
        let lines: Vec<&str> = s.lines().filter(|l| l.contains('|')).collect();
        let lens: Vec<usize> = lines.iter().map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "ragged table:\n{s}");
    }

    #[test]
    fn cell_formats() {
        assert_eq!(TextTable::rmse_time_cell(2.4, 285.0), "2.4000(285)");
        assert_eq!(TextTable::rmse_time_cell(7.9, 0.5), "7.9000(0.50)");
        assert_eq!(TextTable::speedup_time_cell(6.9, 139.0), "6.9(139.0)");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = TextTable::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
