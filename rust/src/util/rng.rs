//! PCG64 pseudo-random number generator plus the sampling helpers the rest
//! of the library needs (uniform, standard normal, permutations, subset
//! selection).
//!
//! Implemented from scratch (offline build). PCG-XSL-RR 128/64 is the same
//! generator family used by `rand::Pcg64`; it is fast, has 2^128 period and
//! excellent statistical quality for simulation workloads. Determinism
//! matters here: every experiment seeds its generators explicitly so tables
//! are reproducible run-to-run.

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed. Two generators with different seeds
    /// produce independent-looking streams.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to expand the seed into state/stream constants so that
        // consecutive seeds give decorrelated streams.
        let mut sm = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let state = ((next() as u128) << 64) | next() as u128;
        let inc = (((next() as u128) << 64) | next() as u128) | 1;
        let mut rng = Pcg64 { state, inc };
        // Warm up: decorrelate from the seed-expansion structure.
        rng.next_u64();
        rng.next_u64();
        rng
    }

    /// Derive an independent child generator (used to give each simulated
    /// cluster rank its own stream).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection method to avoid
    /// modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (polar form avoided: trig form is
    /// branch-free and fine at this scale).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform_in(lo, hi)).collect()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from [0, n) (k ≤ n), in random order.
    /// Used for support-set and test-set selection exactly as the paper's
    /// "selected randomly" protocol.
    pub fn choose_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher-Yates: only the first k positions need shuffling.
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Pcg64::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Pcg64::new(6);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn choose_indices_distinct_and_in_range() {
        let mut r = Pcg64::new(8);
        for _ in 0..50 {
            let k = r.below(20) + 1;
            let idx = r.choose_indices(100, k);
            assert_eq!(idx.len(), k);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in {idx:?}");
            assert!(idx.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg64::new(10);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
