//! Declarative command-line flag parser (offline replacement for `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, defaults, and auto-generated `--help`. Used by the `pgpr`
//! binary, every example, and every bench harness.

use std::collections::BTreeMap;

use crate::util::error::{PgprError, Result};

/// Specification of one flag.
#[derive(Clone, Debug)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
    is_multi: bool,
}

/// Builder-style argument parser.
#[derive(Clone, Debug, Default)]
pub struct Args {
    program: String,
    about: String,
    flags: Vec<FlagSpec>,
    values: BTreeMap<String, String>,
    multi_values: BTreeMap<String, Vec<String>>,
    positionals: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Declare a flag taking a value, with a default.
    pub fn flag(mut self, name: &str, default: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_bool: false,
            is_multi: false,
        });
        self
    }

    /// Declare a required flag (no default).
    pub fn required(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: false,
            is_multi: false,
        });
        self
    }

    /// Declare a boolean switch (false unless present).
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some("false".to_string()),
            is_bool: true,
            is_multi: false,
        });
        self
    }

    /// Declare a repeatable flag: every occurrence appends a value
    /// (e.g. `pgpr serve --model a=a.pgpr --model b=b.pgpr`).
    pub fn multi(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(String::new()),
            is_bool: false,
            is_multi: true,
        });
        self
    }

    /// Render the help text.
    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} [FLAGS]\n\nFLAGS:\n", self.program, self.about, self.program);
        for f in &self.flags {
            let kind = if f.is_bool {
                ""
            } else if f.is_multi {
                " <value> (repeatable)"
            } else {
                " <value>"
            };
            let def = match &f.default {
                Some(d) if !f.is_bool && !f.is_multi => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s.push_str(&format!("  --{}{kind}\n      {}{def}\n", f.name, f.help));
        }
        s.push_str("  --help\n      print this message\n");
        s
    }

    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(mut self, argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(PgprError::Config(self.help_text()));
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .cloned()
                    .ok_or_else(|| {
                        PgprError::Config(format!("unknown flag --{name}\n\n{}", self.help_text()))
                    })?;
                let value = if spec.is_bool {
                    match inline_val {
                        Some(v) => v,
                        None => "true".to_string(),
                    }
                } else {
                    match inline_val {
                        Some(v) => v,
                        None => it.next().ok_or_else(|| {
                            PgprError::Config(format!("flag --{name} expects a value"))
                        })?,
                    }
                };
                if spec.is_multi {
                    self.multi_values.entry(name).or_default().push(value);
                } else {
                    self.values.insert(name, value);
                }
            } else {
                self.positionals.push(arg);
            }
        }
        // Check required flags.
        for f in &self.flags {
            if f.default.is_none() && !self.values.contains_key(&f.name) {
                return Err(PgprError::Config(format!(
                    "missing required flag --{}\n\n{}",
                    f.name,
                    self.help_text()
                )));
            }
        }
        Ok(self)
    }

    /// Parse from the process arguments; on `--help` or error prints and
    /// exits.
    pub fn parse(self) -> Args {
        match self.parse_from(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(PgprError::Config(msg)) => {
                eprintln!("{msg}");
                std::process::exit(if msg.contains("USAGE:") && !msg.contains("unknown") && !msg.contains("missing") { 0 } else { 2 });
            }
            Err(e) => {
                eprintln!("argument error: {e}");
                std::process::exit(2);
            }
        }
    }

    fn raw(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        for f in &self.flags {
            if f.name == name {
                return f.default.clone().unwrap_or_default();
            }
        }
        panic!("flag --{name} was never declared");
    }

    pub fn get(&self, name: &str) -> String {
        self.raw(name)
    }

    pub fn get_usize(&self, name: &str) -> usize {
        let v = self.raw(name);
        v.parse().unwrap_or_else(|_| panic!("flag --{name}: `{v}` is not an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        let v = self.raw(name);
        v.parse().unwrap_or_else(|_| panic!("flag --{name}: `{v}` is not a number"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.raw(name).as_str(), "true" | "1" | "yes")
    }

    /// All values of a repeatable flag, in argv order (empty when the
    /// flag never appeared).
    pub fn get_multi(&self, name: &str) -> Vec<String> {
        debug_assert!(
            self.flags.iter().any(|f| f.name == name && f.is_multi),
            "flag --{name} was never declared as multi"
        );
        self.multi_values.get(name).cloned().unwrap_or_default()
    }

    /// Comma-separated list of usizes, e.g. `--sizes 1000,2000,4000`.
    pub fn get_usize_list(&self, name: &str) -> Vec<usize> {
        let v = self.raw(name);
        v.split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("flag --{name}: `{s}` is not an integer"))
            })
            .collect()
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_defaults() {
        let a = Args::new("t", "test")
            .flag("n", "10", "count")
            .switch("verbose", "talkative")
            .parse_from(argv(&["--n", "32", "--verbose"]))
            .unwrap();
        assert_eq!(a.get_usize("n"), 32);
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn default_applies_when_absent() {
        let a = Args::new("t", "test")
            .flag("n", "10", "count")
            .switch("v", "v")
            .parse_from(argv(&[]))
            .unwrap();
        assert_eq!(a.get_usize("n"), 10);
        assert!(!a.get_bool("v"));
    }

    #[test]
    fn equals_syntax() {
        let a = Args::new("t", "t")
            .flag("sizes", "", "csv list")
            .parse_from(argv(&["--sizes=1,2,3"]))
            .unwrap();
        assert_eq!(a.get_usize_list("sizes"), vec![1, 2, 3]);
    }

    #[test]
    fn unknown_flag_rejected() {
        let r = Args::new("t", "t").parse_from(argv(&["--bogus"]));
        assert!(r.is_err());
    }

    #[test]
    fn missing_required_rejected() {
        let r = Args::new("t", "t").required("path", "p").parse_from(argv(&[]));
        assert!(r.is_err());
    }

    #[test]
    fn multi_flag_accumulates_in_order() {
        let a = Args::new("t", "t")
            .multi("model", "name=path")
            .flag("n", "1", "n")
            .parse_from(argv(&["--model", "a=1", "--n", "2", "--model=b=2"]))
            .unwrap();
        assert_eq!(a.get_multi("model"), vec!["a=1".to_string(), "b=2".to_string()]);
        assert_eq!(a.get_usize("n"), 2);
        // Absent multi flag is an empty list.
        let b = Args::new("t", "t").multi("model", "m").parse_from(argv(&[])).unwrap();
        assert!(b.get_multi("model").is_empty());
    }

    #[test]
    fn positionals_collected() {
        let a = Args::new("t", "t")
            .flag("n", "1", "n")
            .parse_from(argv(&["cmd", "--n", "2", "sub"]))
            .unwrap();
        assert_eq!(a.positionals(), &["cmd".to_string(), "sub".to_string()]);
    }

    #[test]
    fn help_requested_is_config_error_with_usage() {
        let r = Args::new("t", "about-string").parse_from(argv(&["--help"]));
        match r {
            Err(PgprError::Config(msg)) => assert!(msg.contains("USAGE")),
            _ => panic!("expected help"),
        }
    }
}
