//! Tiny scoped-thread helpers shared by the `linalg` kernels, the LMA fit
//! and the `cluster::ThreadCluster` execution backend.
//!
//! No external dependencies: workers are `std::thread::scope` threads that
//! pull indices off an atomic counter. Every parallelized loop in this
//! crate is designed so the arithmetic per output element is identical to
//! the sequential path — results are **bit-identical regardless of the
//! thread count**, which is what lets the backend-equivalence tests assert
//! exact equality between sequential and threaded execution.
//!
//! The global worker count consulted by the linalg kernels defaults to 1
//! (fully deterministic single-threaded execution; the virtual-time
//! `SimCluster` also assumes single-threaded measurement). Raise it with
//! the `PGPR_NUM_THREADS` environment variable or [`set_num_threads`].
//! `ThreadCluster` carries its own worker count and does not consult the
//! global setting.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// True on pool worker threads. Kernels consult [`in_worker`] to stay
    /// sequential inside an already-parallel region, so rank-level and
    /// kernel-level parallelism never multiply into oversubscription.
    static IN_POOL: Cell<bool> = Cell::new(false);
}

/// Whether the current thread is a `util::par` pool worker.
pub fn in_worker() -> bool {
    IN_POOL.with(|c| c.get())
}

/// Number of logical cores reported by the OS (≥ 1).
pub fn available_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a thread-count knob: 0 means "one worker per available core".
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        available_cores()
    } else {
        requested
    }
}

/// Global worker count for the linalg kernels. Defaults to 1; initialized
/// once from `PGPR_NUM_THREADS` (where 0 means all cores).
pub fn num_threads() -> usize {
    let v = GLOBAL_THREADS.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let n = std::env::var("PGPR_NUM_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .map(resolve_threads)
        .unwrap_or(1)
        .max(1);
    GLOBAL_THREADS.store(n, Ordering::Relaxed);
    n
}

/// Override the global linalg worker count (0 = one worker per core).
pub fn set_num_threads(n: usize) {
    GLOBAL_THREADS.store(resolve_threads(n).max(1), Ordering::Relaxed);
}

/// Map `f` over `0..n` on up to `threads` scoped workers, returning the
/// results in index order. Falls back to a plain sequential loop when one
/// worker suffices. Panics in `f` propagate to the caller when the scope
/// joins.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = threads.min(n).max(1);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let done: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|s| {
        for w in 0..workers {
            let (next, done, f) = (&next, &done, &f);
            s.spawn(move || {
                IN_POOL.with(|c| c.set(true));
                let _prof = crate::obs::prof::register_thread(&format!("par-{w}"));
                let mut local: Vec<(usize, T)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i)));
                }
                done.lock().unwrap().extend(local);
            });
        }
    });
    let mut pairs = done.into_inner().unwrap();
    pairs.sort_by_key(|&(i, _)| i);
    pairs.into_iter().map(|(_, t)| t).collect()
}

/// Split a row-major buffer of `rows × cols` into per-worker chunks of
/// `per` rows and run `kernel(chunk, row0, row1)` on scoped threads. The
/// chunks are disjoint `&mut` slices, so kernels write without locks;
/// callers pick `per` so chunk boundaries preserve whatever row grouping
/// the sequential kernel uses (bit-identical outputs). Panics in `kernel`
/// propagate when the scope joins.
pub fn run_row_chunks<'a, K>(data: &'a mut [f64], rows: usize, cols: usize, per: usize, kernel: K)
where
    K: Fn(&mut [f64], usize, usize) + Sync + Send + Copy + 'a,
{
    let mut rest: &mut [f64] = data;
    let mut i0 = 0;
    std::thread::scope(|s| {
        while i0 < rows {
            let i1 = (i0 + per).min(rows);
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut((i1 - i0) * cols);
            rest = tail;
            let (lo, hi) = (i0, i1);
            s.spawn(move || {
                IN_POOL.with(|c| c.set(true));
                let _prof = crate::obs::prof::register_thread("par-row");
                kernel(chunk, lo, hi)
            });
            i0 = i1;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order() {
        for threads in [1, 2, 4, 8] {
            let got = parallel_map(37, threads, |i| i * i);
            let want: Vec<usize> = (0..37).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn parallel_map_empty_and_single() {
        assert!(parallel_map(0, 4, |i| i).is_empty());
        assert_eq!(parallel_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn parallel_map_handles_fallible_bodies() {
        let out: Vec<Result<usize, String>> =
            parallel_map(10, 3, |i| if i == 7 { Err(format!("bad {i}")) } else { Ok(i) });
        assert!(out[7].is_err());
        assert_eq!(out[3], Ok(3));
    }

    #[test]
    fn row_chunks_cover_all_rows_disjointly() {
        let (rows, cols) = (23, 7);
        let mut data = vec![0.0f64; rows * cols];
        run_row_chunks(&mut data, rows, cols, 5, |chunk, lo, hi| {
            for r in 0..(hi - lo) {
                for c in 0..cols {
                    chunk[r * cols + c] += (lo + r) as f64;
                }
            }
        });
        for i in 0..rows {
            for c in 0..cols {
                assert_eq!(data[i * cols + c], i as f64, "row {i} col {c}");
            }
        }
    }

    #[test]
    fn resolve_and_cores_sane() {
        assert!(available_cores() >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }
}
