//! Deterministic fault-injection harness (std-only, zero-cost when
//! disabled).
//!
//! A *fault point* is a named site in the serving pipeline that can be
//! armed to misbehave on purpose: panic, stall, corrupt. Points are
//! armed either from the environment (`PGPR_FAULT=point[:arg][,..]`,
//! read once at first use) or programmatically from tests
//! ([`arm`] / [`reset`]). The catalog:
//!
//! | point             | arg                 | behaviour at the site        |
//! |-------------------|---------------------|------------------------------|
//! | `batcher_panic`   | shots (default 1)   | batcher loop panics on the next `shots` dequeues |
//! | `engine_stall_ms` | milliseconds        | every engine predict sleeps first (level-triggered) |
//! | `artifact_corrupt`| shots (default 1)   | next `shots` artifact loads see a flipped payload bit |
//! | `queue_stick`     | milliseconds        | batcher dequeue + observe drain stall first (level-triggered) |
//! | `cpu_saturation_pct` | percent          | `obs::prof::cpu_saturation()` reads arg/100 (level-triggered) |
//!
//! Disabled cost is one relaxed atomic load per check ([`ARMED`] stays
//! `false` until something is armed), so the hooks can sit on the
//! request hot path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

/// Batcher loop panics at dequeue (edge-triggered, consumes a shot).
pub const BATCHER_PANIC: &str = "batcher_panic";
/// Engine predict sleeps `arg` ms (level-triggered).
pub const ENGINE_STALL_MS: &str = "engine_stall_ms";
/// Artifact load sees a flipped payload bit (edge-triggered).
pub const ARTIFACT_CORRUPT: &str = "artifact_corrupt";
/// Batcher dequeue / observe drain stalls `arg` ms (level-triggered).
pub const QUEUE_STICK: &str = "queue_stick";
/// CPU saturation reads `arg`/100 instead of the sampler's EWMA
/// (level-triggered), for deterministic `cpu`-shed tests.
pub const CPU_SATURATION_PCT: &str = "cpu_saturation_pct";

/// One armed point: optional argument and a remaining-shot budget
/// (`None` = unlimited, i.e. level-triggered).
#[derive(Clone, Copy, Debug)]
struct FaultState {
    arg: u64,
    shots: Option<u64>,
}

/// Fast path: `false` until anything is ever armed, so disabled checks
/// are a single relaxed load.
static ARMED: AtomicBool = AtomicBool::new(false);

fn table() -> &'static Mutex<HashMap<String, FaultState>> {
    static TABLE: OnceLock<Mutex<HashMap<String, FaultState>>> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut map = HashMap::new();
        if let Ok(spec) = std::env::var("PGPR_FAULT") {
            for (point, state) in parse_spec(&spec) {
                map.insert(point, state);
            }
        }
        if !map.is_empty() {
            ARMED.store(true, Ordering::SeqCst);
        }
        Mutex::new(map)
    })
}

/// Default shot budget for a point: the injected-failure points are
/// one-shot (so the respawned batcher doesn't re-panic forever), the
/// stall points are level-triggered.
fn default_shots(point: &str) -> Option<u64> {
    match point {
        BATCHER_PANIC | ARTIFACT_CORRUPT => Some(1),
        _ => None,
    }
}

/// Parse `point[:arg][,point[:arg]]…` into per-point states. For the
/// one-shot points the arg is the shot count; for the stall points it
/// is the millisecond argument. Unknown names are kept verbatim so test
/// harnesses can define ad-hoc points.
fn parse_spec(spec: &str) -> Vec<(String, FaultState)> {
    spec.split(',')
        .filter_map(|part| {
            let part = part.trim();
            if part.is_empty() {
                return None;
            }
            let (point, arg) = match part.split_once(':') {
                Some((p, a)) => (p.trim(), a.trim().parse::<u64>().unwrap_or(0)),
                None => (part, 0),
            };
            let shots = match default_shots(point) {
                // For one-shot points a non-zero arg overrides the budget.
                Some(d) => Some(if arg > 0 { arg } else { d }),
                None => None,
            };
            Some((point.to_string(), FaultState { arg, shots }))
        })
        .collect()
}

/// Arm one fault point programmatically (tests). `arg` is the
/// millisecond argument for level points and the shot budget for
/// one-shot points (0 = point default).
pub fn arm(point: &str, arg: u64) {
    let mut map = table().lock().unwrap();
    let shots = match default_shots(point) {
        Some(d) => Some(if arg > 0 { arg } else { d }),
        None => None,
    };
    map.insert(point.to_string(), FaultState { arg, shots });
    ARMED.store(true, Ordering::SeqCst);
}

/// Disarm every fault point (tests). The fast path stays hot once armed
/// — the per-check cost after a `reset` is still one load + one short
/// lock, which only tests ever pay.
pub fn reset() {
    table().lock().unwrap().clear();
}

/// Consume one shot of an edge-triggered point. Returns the point's arg
/// when it fires, `None` when disarmed or exhausted. Level-triggered
/// points also fire here (without consuming).
pub fn fire(point: &str) -> Option<u64> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let mut map = table().lock().unwrap();
    let state = map.get_mut(point)?;
    let arg = state.arg;
    match &mut state.shots {
        Some(0) => return None,
        Some(n) => {
            *n -= 1;
            if *n == 0 {
                map.remove(point);
            }
        }
        None => {}
    }
    Some(arg)
}

/// Observe a level-triggered point without consuming shots. Returns the
/// arg when armed (and not exhausted).
pub fn peek(point: &str) -> Option<u64> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let map = table().lock().unwrap();
    let state = map.get(point)?;
    if state.shots == Some(0) {
        return None;
    }
    Some(state.arg)
}

/// Sleep for a level point's armed duration, if armed. Convenience for
/// the stall hooks.
pub fn stall(point: &str) {
    if let Some(ms) = peek(point) {
        if ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
}

/// Serialize tests that arm fault points: the table is process-wide, so
/// concurrent arming tests would clobber each other. Lock this for the
/// whole armed section and [`reset`] before releasing.
pub fn serial_guard() -> std::sync::MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_points_never_fire() {
        let _g = serial_guard();
        reset();
        assert_eq!(fire(BATCHER_PANIC), None);
        assert_eq!(peek(ENGINE_STALL_MS), None);
    }

    #[test]
    fn one_shot_point_fires_exactly_n_times() {
        let _g = serial_guard();
        reset();
        arm(BATCHER_PANIC, 0); // default: 1 shot
        assert_eq!(fire(BATCHER_PANIC), Some(0));
        assert_eq!(fire(BATCHER_PANIC), None);
        arm(ARTIFACT_CORRUPT, 2);
        assert_eq!(fire(ARTIFACT_CORRUPT), Some(2));
        assert_eq!(fire(ARTIFACT_CORRUPT), Some(2));
        assert_eq!(fire(ARTIFACT_CORRUPT), None);
        reset();
    }

    #[test]
    fn level_point_peeks_without_consuming() {
        let _g = serial_guard();
        reset();
        arm(ENGINE_STALL_MS, 25);
        assert_eq!(peek(ENGINE_STALL_MS), Some(25));
        assert_eq!(peek(ENGINE_STALL_MS), Some(25));
        assert_eq!(fire(ENGINE_STALL_MS), Some(25), "fire observes level points too");
        assert_eq!(peek(ENGINE_STALL_MS), Some(25));
        reset();
        assert_eq!(peek(ENGINE_STALL_MS), None);
    }

    #[test]
    fn spec_syntax_parses_points_args_and_lists() {
        let _g = serial_guard();
        let parsed = parse_spec("batcher_panic:3, engine_stall_ms:40 ,queue_stick");
        assert_eq!(parsed.len(), 3);
        assert_eq!(parsed[0].0, BATCHER_PANIC);
        assert_eq!(parsed[0].1.shots, Some(3));
        assert_eq!(parsed[1].0, ENGINE_STALL_MS);
        assert_eq!(parsed[1].1.arg, 40);
        assert_eq!(parsed[1].1.shots, None);
        assert_eq!(parsed[2].1.arg, 0);
        // Bare one-shot point defaults to a single shot.
        let parsed = parse_spec("artifact_corrupt");
        assert_eq!(parsed[0].1.shots, Some(1));
        // Empty / whitespace specs arm nothing.
        assert!(parse_spec(" , ,").is_empty());
    }
}
