//! Self-contained substrates: error type, PRNG, JSON, CSV, CLI parsing,
//! bench harness, scoped-thread worker pool, progress logging, table
//! rendering and a tiny property-testing helper.
//!
//! Everything here is written from scratch because the build environment is
//! offline: the default build has **no external crates at all**; the only
//! optional one is `xla` (PJRT bindings) behind the `pjrt` feature.

pub mod error;
pub mod par;
pub mod rng;
pub mod json;
pub mod csv;
pub mod cli;
pub mod bench;
pub mod fault;
pub mod tables;
pub mod proptest;
pub mod timer;
