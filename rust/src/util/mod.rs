//! Self-contained substrates: error type, PRNG, JSON, CSV, CLI parsing,
//! bench harness, progress logging, table rendering and a tiny
//! property-testing helper.
//!
//! Everything here is written from scratch because the build environment is
//! offline: the only external crates are `xla` (PJRT bindings) and `anyhow`.

pub mod error;
pub mod rng;
pub mod json;
pub mod csv;
pub mod cli;
pub mod bench;
pub mod tables;
pub mod proptest;
pub mod timer;
