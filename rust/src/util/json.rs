//! Minimal JSON value model, writer and recursive-descent parser.
//!
//! Used for artifact manifests (`artifacts/manifest.json` written by the
//! python AOT pass), experiment configs and machine-readable results. Only
//! the JSON subset those need is supported: objects, arrays, strings,
//! numbers, booleans, null; `\uXXXX` escapes are parsed (BMP only, which is
//! all our producers emit).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::{PgprError, Result};

/// A parsed JSON value. Objects use a BTreeMap so serialization is
/// deterministic (important for golden-file tests).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(PgprError::Data(format!(
                "trailing characters at byte {} of JSON input",
                p.pos
            )));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ----- typed accessors (used by manifest/config readers) -----

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// A numeric array as `Vec<f64>`; `None` if not an array or any
    /// element is non-numeric (used by the `/predict` row parser).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        let xs = self.as_arr()?;
        let mut out = Vec::with_capacity(xs.len());
        for x in xs {
            out.push(x.as_f64()?);
        }
        Some(out)
    }

    /// `obj.get("a").get("b")`-style access that tolerates missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Required-field accessor with a readable error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| PgprError::Data(format!("missing JSON field `{key}`")))
    }

    // ----- builders -----

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn err<T>(&self, msg: &str) -> Result<T> {
        Err(PgprError::Data(format!("JSON parse error at byte {}: {msg}", self.pos)))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected `{}`", b as char))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a JSON value"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected `{word}`"))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            Ok(x) => Ok(Json::Num(x)),
            Err(_) => self.err(&format!("bad number `{text}`")),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return self.err("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| {
                                        PgprError::Data("non-utf8 \\u escape".into())
                                    })?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| PgprError::Data("bad \\u escape".into()))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| PgprError::Data("non-utf8 JSON".into()))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return self.err("expected `,` or `]`"),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected `,` or `}`"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": true}, "e": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2], Json::Num(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "hi\nthere");
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é");
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 7, "s": "x", "b": false}"#).unwrap();
        assert_eq!(v.req("n").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert!(v.req("missing").is_err());
        assert_eq!(v.get("n").unwrap().as_str(), None);
    }

    #[test]
    fn f64_vec_accessor() {
        let v = Json::parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.as_f64_vec(), Some(vec![1.0, 2.5, -3.0]));
        assert_eq!(Json::parse(r#"[1, "x"]"#).unwrap().as_f64_vec(), None);
        assert_eq!(Json::parse("7").unwrap().as_f64_vec(), None);
        assert_eq!(Json::parse("[]").unwrap().as_f64_vec(), Some(vec![]));
    }

    #[test]
    fn deep_nesting_roundtrip() {
        let mut s = String::new();
        for _ in 0..64 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..64 {
            s.push(']');
        }
        let v = Json::parse(&s).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
