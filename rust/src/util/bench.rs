//! Micro/macro benchmark harness (offline replacement for criterion).
//!
//! Every `[[bench]]` target is a plain `fn main()` (harness = false) that
//! builds a [`BenchSuite`], registers cases, and calls [`BenchSuite::run`].
//! The harness warms up, runs a fixed-duration measurement window, and
//! reports median / p10 / p90 wall-clock per iteration plus optional
//! throughput. Results are also appended to `results/bench/*.csv` so the
//! EXPERIMENTS.md §Perf iterations have a machine-readable trail.

use std::time::Instant;

use crate::util::csv::CsvTable;
use crate::util::error::Result;
use crate::util::json::Json;

/// One measured case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
    /// Optional user-supplied work units per iteration (e.g. flops) for
    /// throughput reporting.
    pub units_per_iter: Option<f64>,
}

/// Harness configuration (overridable via env so `cargo bench` stays fast
/// in CI but can be cranked up for the perf pass).
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub target_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        let fast = std::env::var("PGPR_BENCH_FAST").is_ok();
        BenchConfig {
            warmup_iters: if fast { 1 } else { 2 },
            min_iters: if fast { 2 } else { 5 },
            max_iters: if fast { 5 } else { 50 },
            target_seconds: if fast { 0.2 } else { 1.0 },
        }
    }
}

/// A suite of benchmark cases sharing a name and output CSV.
pub struct BenchSuite {
    pub suite: String,
    pub cfg: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(suite: &str) -> Self {
        println!("\n=== bench suite: {suite} ===");
        BenchSuite { suite: suite.to_string(), cfg: BenchConfig::default(), results: Vec::new() }
    }

    /// Measure `f` repeatedly. `f` should perform one full iteration of the
    /// workload and return a value that is consumed (to defeat DCE, return
    /// something data-dependent and pass it to `std::hint::black_box`
    /// inside `f`).
    pub fn case(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        for _ in 0..self.cfg.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let window_start = Instant::now();
        while samples.len() < self.cfg.min_iters
            || (window_start.elapsed().as_secs_f64() < self.cfg.target_seconds
                && samples.len() < self.cfg.max_iters)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            let idx = ((samples.len() - 1) as f64 * p).round() as usize;
            samples[idx]
        };
        let res = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            median_s: pct(0.5),
            p10_s: pct(0.1),
            p90_s: pct(0.9),
            units_per_iter: None,
        };
        println!(
            "  {name:<48} median {:>12}  p10 {:>12}  p90 {:>12}  ({} iters)",
            fmt_time(res.median_s),
            fmt_time(res.p10_s),
            fmt_time(res.p90_s),
            res.iters
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Like [`case`] but reports throughput in `units` per second (units =
    /// e.g. flops, points, requests).
    pub fn case_with_throughput(&mut self, name: &str, units: f64, f: impl FnMut()) {
        self.case(name, f);
        let last = self.results.last_mut().unwrap();
        last.units_per_iter = Some(units);
        println!(
            "  {:<48} throughput {:.3e} units/s",
            "", units / last.median_s
        );
    }

    /// Write results CSV under `results/bench/<suite>.csv` and print a
    /// footer. Call at the end of each bench main().
    pub fn finish(&self) {
        let mut t = CsvTable::new(&["case", "iters", "median_s", "p10_s", "p90_s", "units_per_iter"]);
        for r in &self.results {
            t.push_row(vec![
                r.name.clone(),
                r.iters.to_string(),
                format!("{:.9}", r.median_s),
                format!("{:.9}", r.p10_s),
                format!("{:.9}", r.p90_s),
                r.units_per_iter.map(|u| format!("{u}")).unwrap_or_default(),
            ]);
        }
        let path = format!("results/bench/{}.csv", self.suite);
        if let Err(e) = t.write_path(&path) {
            eprintln!("warning: could not write {path}: {e}");
        } else {
            println!("=== wrote {path} ===");
        }
    }
}

/// Write a machine-readable benchmark record (the `BENCH_*.json` files
/// tracked across PRs for the perf trajectory). Creates parent
/// directories as needed and appends a trailing newline.
pub fn write_json_record(path: impl AsRef<std::path::Path>, record: &Json) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, record.to_string() + "\n")?;
    Ok(())
}

/// Human-readable duration.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_cases_and_records() {
        std::env::set_var("PGPR_BENCH_FAST", "1");
        let mut suite = BenchSuite::new("unit_test_suite");
        suite.cfg = BenchConfig { warmup_iters: 1, min_iters: 2, max_iters: 3, target_seconds: 0.01 };
        suite.case("noop", || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(suite.results.len(), 1);
        let r = &suite.results[0];
        assert!(r.iters >= 2);
        assert!(r.p10_s <= r.median_s && r.median_s <= r.p90_s);
    }

    #[test]
    fn json_record_roundtrips() {
        let dir = std::env::temp_dir().join("pgpr_bench_json_test");
        let path = dir.join("BENCH_test.json");
        let rec = Json::obj(vec![
            ("bench", Json::Str("unit".into())),
            ("speedup", Json::Num(2.5)),
        ]);
        write_json_record(&path, &rec).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let back = Json::parse(text.trim()).unwrap();
        assert_eq!(back.req("bench").unwrap().as_str(), Some("unit"));
        assert_eq!(back.req("speedup").unwrap().as_f64(), Some(2.5));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
