//! CSV reading/writing for datasets and experiment results.
//!
//! Deliberately small: comma separator, optional header, numeric columns,
//! double-quote escaping for string cells. This is the on-disk format for
//! generated datasets (`pgpr data gen`) and for every experiment's
//! `results/*.csv` output.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::util::error::{PgprError, Result};

/// An in-memory CSV table with a header row.
#[derive(Clone, Debug, Default)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new(header: &[&str]) -> Self {
        CsvTable { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row of already-formatted cells.
    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity != header arity");
        self.rows.push(cells);
    }

    /// Append a row of f64 cells (formatted with enough precision to
    /// round-trip).
    pub fn push_nums(&mut self, cells: &[f64]) {
        self.push_row(cells.iter().map(|x| format!("{x:.9}")).collect());
    }

    /// Column index by name.
    pub fn col(&self, name: &str) -> Result<usize> {
        self.header
            .iter()
            .position(|h| h == name)
            .ok_or_else(|| PgprError::Data(format!("CSV column `{name}` not found")))
    }

    /// Entire column parsed as f64.
    pub fn col_f64(&self, name: &str) -> Result<Vec<f64>> {
        let c = self.col(name)?;
        self.rows
            .iter()
            .map(|r| {
                r[c].parse::<f64>()
                    .map_err(|_| PgprError::Data(format!("bad number `{}` in column {name}", r[c])))
            })
            .collect()
    }

    pub fn write_path(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", encode_row(&self.header))?;
        for row in &self.rows {
            writeln!(w, "{}", encode_row(row))?;
        }
        Ok(())
    }

    pub fn read_path(path: impl AsRef<Path>) -> Result<CsvTable> {
        let reader = BufReader::new(File::open(&path)?);
        let mut lines = reader.lines();
        let header = match lines.next() {
            Some(line) => parse_row(&line?)?,
            None => return Err(PgprError::Data("empty CSV file".into())),
        };
        let mut rows = Vec::new();
        for line in lines {
            let line = line?;
            if line.is_empty() {
                continue;
            }
            let row = parse_row(&line)?;
            if row.len() != header.len() {
                return Err(PgprError::Data(format!(
                    "CSV row arity {} != header arity {}",
                    row.len(),
                    header.len()
                )));
            }
            rows.push(row);
        }
        Ok(CsvTable { header, rows })
    }
}

fn needs_quoting(cell: &str) -> bool {
    cell.contains(',') || cell.contains('"') || cell.contains('\n')
}

fn encode_row(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| {
            if needs_quoting(c) {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

fn parse_row(line: &str) -> Result<Vec<String>> {
    let bytes = line.as_bytes();
    let mut cells = Vec::new();
    let mut cur = String::new();
    let mut i = 0;
    let mut in_quotes = false;
    while i < bytes.len() {
        let b = bytes[i];
        if in_quotes {
            if b == b'"' {
                if bytes.get(i + 1) == Some(&b'"') {
                    cur.push('"');
                    i += 2;
                    continue;
                }
                in_quotes = false;
                i += 1;
            } else {
                // Copy one UTF-8 scalar.
                let rest = &line[i..];
                let c = rest.chars().next().unwrap();
                cur.push(c);
                i += c.len_utf8();
            }
        } else {
            match b {
                b',' => {
                    cells.push(std::mem::take(&mut cur));
                    i += 1;
                }
                b'"' if cur.is_empty() => {
                    in_quotes = true;
                    i += 1;
                }
                _ => {
                    let rest = &line[i..];
                    let c = rest.chars().next().unwrap();
                    cur.push(c);
                    i += c.len_utf8();
                }
            }
        }
    }
    if in_quotes {
        return Err(PgprError::Data("unterminated quote in CSV row".into()));
    }
    cells.push(cur);
    Ok(cells)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_via_disk() {
        let mut t = CsvTable::new(&["a", "b,with,commas", "c"]);
        t.push_row(vec!["1".into(), "x\"y".into(), "plain".into()]);
        t.push_nums(&[0.5, -3.0, 1e-9]);
        let dir = std::env::temp_dir().join("pgpr_csv_test");
        let path = dir.join("t.csv");
        t.write_path(&path).unwrap();
        let back = CsvTable::read_path(&path).unwrap();
        assert_eq!(back.header, t.header);
        assert_eq!(back.rows, t.rows);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn col_f64_parses() {
        let mut t = CsvTable::new(&["x"]);
        t.push_nums(&[1.5]);
        t.push_nums(&[-2.0]);
        assert_eq!(t.col_f64("x").unwrap(), vec![1.5, -2.0]);
        assert!(t.col_f64("y").is_err());
    }

    #[test]
    fn quoted_cells() {
        let row = parse_row(r#"a,"b,c","d""e""#).unwrap();
        assert_eq!(row, vec!["a", "b,c", "d\"e"]);
    }

    #[test]
    fn arity_mismatch_rejected() {
        let dir = std::env::temp_dir().join("pgpr_csv_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "a,b\n1\n").unwrap();
        assert!(CsvTable::read_path(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
