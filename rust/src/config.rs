//! Configuration types for the LMA engine, the baselines, the cluster
//! topology and the experiment harnesses, with JSON (de)serialization so
//! runs are fully reproducible from a config file.

use crate::obs::quality::ScoreMode;
use crate::util::error::{PgprError, Result};
use crate::util::json::Json;

/// Configuration of the LMA method (Section 3).
#[derive(Clone, Debug, PartialEq)]
pub struct LmaConfig {
    /// M — number of blocks (and, for parallel LMA, of workers).
    pub num_blocks: usize,
    /// B — Markov order, 0 ≤ B ≤ M−1. B=0 reduces to PIC, B=M−1 to FGP.
    pub markov_order: usize,
    /// |S| — support set size.
    pub support_size: usize,
    /// Seed for support-set selection and partition initialization.
    pub seed: u64,
    /// Partitioning strategy for D (and U).
    pub partition: PartitionStrategy,
    /// Use the PJRT artifact path for covariance blocks when available.
    pub use_pjrt: bool,
}

impl Default for LmaConfig {
    fn default() -> Self {
        LmaConfig {
            num_blocks: 8,
            markov_order: 1,
            support_size: 128,
            seed: 0,
            partition: PartitionStrategy::KMeans { iters: 10 },
            use_pjrt: false,
        }
    }
}

/// How D/U are split into the M correlated blocks (paper footnote 1:
/// "a simple parallelized clustering scheme").
#[derive(Clone, Debug, PartialEq)]
pub enum PartitionStrategy {
    /// k-means on the (lengthscale-scaled) inputs — the Chen et al. (2013)
    /// scheme the paper cites.
    KMeans { iters: usize },
    /// Contiguous split in input order (useful for 1-D demos / tests).
    Contiguous,
    /// Random assignment (ablation: shows why correlated blocks matter).
    Random,
}

impl LmaConfig {
    pub fn validate(&self, data_size: usize) -> Result<()> {
        if self.num_blocks == 0 {
            return Err(PgprError::Config("num_blocks must be ≥ 1".into()));
        }
        if self.markov_order >= self.num_blocks {
            return Err(PgprError::Config(format!(
                "markov_order B={} must satisfy B ≤ M−1={}",
                self.markov_order,
                self.num_blocks - 1
            )));
        }
        if self.support_size == 0 {
            return Err(PgprError::Config("support_size must be ≥ 1".into()));
        }
        if data_size < self.num_blocks {
            return Err(PgprError::Config(format!(
                "data size {} < num_blocks {}",
                data_size, self.num_blocks
            )));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let part = match &self.partition {
            PartitionStrategy::KMeans { iters } => {
                Json::obj(vec![("kind", Json::Str("kmeans".into())), ("iters", Json::Num(*iters as f64))])
            }
            PartitionStrategy::Contiguous => Json::obj(vec![("kind", Json::Str("contiguous".into()))]),
            PartitionStrategy::Random => Json::obj(vec![("kind", Json::Str("random".into()))]),
        };
        Json::obj(vec![
            ("num_blocks", Json::Num(self.num_blocks as f64)),
            ("markov_order", Json::Num(self.markov_order as f64)),
            ("support_size", Json::Num(self.support_size as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("partition", part),
            ("use_pjrt", Json::Bool(self.use_pjrt)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<LmaConfig> {
        let partition = match j.get("partition") {
            None => PartitionStrategy::KMeans { iters: 10 },
            Some(p) => match p.req("kind")?.as_str() {
                Some("kmeans") => PartitionStrategy::KMeans {
                    iters: p.get("iters").and_then(|v| v.as_usize()).unwrap_or(10),
                },
                Some("contiguous") => PartitionStrategy::Contiguous,
                Some("random") => PartitionStrategy::Random,
                other => {
                    return Err(PgprError::Config(format!("unknown partition kind {other:?}")))
                }
            },
        };
        Ok(LmaConfig {
            num_blocks: j.req("num_blocks")?.as_usize().ok_or_else(bad("num_blocks"))?,
            markov_order: j.req("markov_order")?.as_usize().ok_or_else(bad("markov_order"))?,
            support_size: j.req("support_size")?.as_usize().ok_or_else(bad("support_size"))?,
            seed: j.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
            partition,
            use_pjrt: j.get("use_pjrt").and_then(|v| v.as_bool()).unwrap_or(false),
        })
    }
}

fn bad(field: &'static str) -> impl Fn() -> PgprError {
    move || PgprError::Config(format!("field `{field}` must be a non-negative integer"))
}

/// Options for the serving front end (`pgpr serve` / `server::http`):
/// where to listen, how the micro-batcher trades latency for batch
/// occupancy, and how long idle keep-alive connections are held.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeOptions {
    /// HTTP listen address, e.g. `127.0.0.1:8080` (`127.0.0.1:0` for an
    /// ephemeral port). The CLI treats an empty string as "stdin line
    /// protocol instead of HTTP".
    pub listen: String,
    /// Connection worker threads.
    pub workers: usize,
    /// Micro-batch flush threshold in rows.
    pub batch_size: usize,
    /// Partial-batch flush deadline in microseconds: a lone request is
    /// answered within this bound even if the batch never fills.
    pub max_delay_us: u64,
    /// Bounded request-queue capacity (full queue ⇒ HTTP 503).
    pub queue_capacity: usize,
    /// Honor HTTP/1.1 keep-alive: serve multiple requests per connection
    /// (`false` ⇒ legacy one-request-per-connection `Connection: close`).
    pub keep_alive: bool,
    /// How long an idle keep-alive connection is held open before the
    /// worker closes it, milliseconds.
    pub idle_timeout_ms: u64,
    /// Requests served on one connection before it is closed (bounds how
    /// long a single client can monopolize a connection worker).
    pub max_conn_requests: usize,
    /// Serve predictions through the reduced-precision f32 U-side path
    /// (`PredictMode::F32U`): one-time f32 copies of the context tensors,
    /// f64 accumulation, predictive mean within 1e-5 relative of the f64
    /// path. Centralized engines only — parallel engines keep serving the
    /// exact f64 path regardless.
    pub f32_u: bool,
    /// Per-request stage tracing: queue-wait/batch-form/engine-phase
    /// attribution into `pgpr_stage_seconds` histograms, the
    /// `/debug/trace` ring and `?trace=1` inline breakdowns. On by
    /// default; `--no-trace` turns the whole layer off.
    pub trace: bool,
    /// Capacity of the per-model trace ring buffer (`/debug/trace`
    /// serves the last N completed request traces).
    pub trace_ring: usize,
    /// Log a structured `slow_request` event for any request slower than
    /// this many microseconds end-to-end (0 disables the watchdog).
    pub slow_request_us: u64,
    /// Default per-model admission SLO in milliseconds: when the
    /// estimated queue delay (depth × rolling per-batch latency) exceeds
    /// this bound, `/predict` sheds with `503 + Retry-After` instead of
    /// queueing past the point clients would time out. 0 disables the
    /// gate; per-model overrides come from `--model name=path,slo=X`.
    pub slo_ms: u64,
    /// Deadline assigned to requests that carry no `X-Deadline-Ms`
    /// header, milliseconds. Expired requests are dropped at
    /// batch-formation time (shed in microseconds, never computed).
    /// 0 means requests without the header have no deadline.
    pub default_deadline_ms: u64,
    /// Continuous resource profiling: the background sampler thread,
    /// the `/debug/prof` endpoint, and the process/thread gauges on
    /// `/metrics`. On by default; `--no-prof` turns the layer off.
    pub prof: bool,
    /// Sampler period in milliseconds.
    pub prof_interval_ms: u64,
    /// Capacity of the profile sample ring (`/debug/prof` serves the
    /// last N snapshots).
    pub prof_ring: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            listen: "127.0.0.1:8080".to_string(),
            workers: 4,
            batch_size: 16,
            max_delay_us: 2000,
            queue_capacity: 1024,
            keep_alive: true,
            idle_timeout_ms: 5000,
            max_conn_requests: 1000,
            f32_u: false,
            trace: true,
            trace_ring: 256,
            slow_request_us: 0,
            slo_ms: 0,
            default_deadline_ms: 0,
            prof: true,
            prof_interval_ms: 1000,
            prof_ring: 256,
        }
    }
}

impl ServeOptions {
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(PgprError::Config("serve: workers must be ≥ 1".into()));
        }
        if self.batch_size == 0 {
            return Err(PgprError::Config("serve: batch_size must be ≥ 1".into()));
        }
        if self.queue_capacity == 0 {
            return Err(PgprError::Config("serve: queue_capacity must be ≥ 1".into()));
        }
        if self.keep_alive && (self.idle_timeout_ms == 0 || self.max_conn_requests == 0) {
            return Err(PgprError::Config(
                "serve: keep-alive needs idle_timeout_ms ≥ 1 and max_conn_requests ≥ 1".into(),
            ));
        }
        if self.trace && self.trace_ring == 0 {
            return Err(PgprError::Config(
                "serve: tracing needs trace_ring ≥ 1 (or disable tracing)".into(),
            ));
        }
        if self.prof && (self.prof_ring == 0 || self.prof_interval_ms == 0) {
            return Err(PgprError::Config(
                "serve: profiling needs prof_ring ≥ 1 and prof_interval_ms ≥ 1 \
                 (or disable profiling)"
                    .into(),
            ));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("listen", Json::Str(self.listen.clone())),
            ("workers", Json::Num(self.workers as f64)),
            ("batch_size", Json::Num(self.batch_size as f64)),
            ("max_delay_us", Json::Num(self.max_delay_us as f64)),
            ("queue_capacity", Json::Num(self.queue_capacity as f64)),
            ("keep_alive", Json::Bool(self.keep_alive)),
            ("idle_timeout_ms", Json::Num(self.idle_timeout_ms as f64)),
            ("max_conn_requests", Json::Num(self.max_conn_requests as f64)),
            ("f32_u", Json::Bool(self.f32_u)),
            ("trace", Json::Bool(self.trace)),
            ("trace_ring", Json::Num(self.trace_ring as f64)),
            ("slow_request_us", Json::Num(self.slow_request_us as f64)),
            ("slo_ms", Json::Num(self.slo_ms as f64)),
            ("default_deadline_ms", Json::Num(self.default_deadline_ms as f64)),
            ("prof", Json::Bool(self.prof)),
            ("prof_interval_ms", Json::Num(self.prof_interval_ms as f64)),
            ("prof_ring", Json::Num(self.prof_ring as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ServeOptions> {
        let d = ServeOptions::default();
        Ok(ServeOptions {
            listen: j
                .get("listen")
                .and_then(|v| v.as_str())
                .unwrap_or(&d.listen)
                .to_string(),
            workers: j.get("workers").and_then(|v| v.as_usize()).unwrap_or(d.workers),
            batch_size: j
                .get("batch_size")
                .and_then(|v| v.as_usize())
                .unwrap_or(d.batch_size),
            max_delay_us: j
                .get("max_delay_us")
                .and_then(|v| v.as_usize())
                .unwrap_or(d.max_delay_us as usize) as u64,
            queue_capacity: j
                .get("queue_capacity")
                .and_then(|v| v.as_usize())
                .unwrap_or(d.queue_capacity),
            keep_alive: j.get("keep_alive").and_then(|v| v.as_bool()).unwrap_or(d.keep_alive),
            idle_timeout_ms: j
                .get("idle_timeout_ms")
                .and_then(|v| v.as_usize())
                .unwrap_or(d.idle_timeout_ms as usize) as u64,
            max_conn_requests: j
                .get("max_conn_requests")
                .and_then(|v| v.as_usize())
                .unwrap_or(d.max_conn_requests),
            f32_u: j.get("f32_u").and_then(|v| v.as_bool()).unwrap_or(d.f32_u),
            trace: j.get("trace").and_then(|v| v.as_bool()).unwrap_or(d.trace),
            trace_ring: j
                .get("trace_ring")
                .and_then(|v| v.as_usize())
                .unwrap_or(d.trace_ring),
            slow_request_us: j
                .get("slow_request_us")
                .and_then(|v| v.as_usize())
                .unwrap_or(d.slow_request_us as usize) as u64,
            slo_ms: j.get("slo_ms").and_then(|v| v.as_usize()).unwrap_or(d.slo_ms as usize)
                as u64,
            default_deadline_ms: j
                .get("default_deadline_ms")
                .and_then(|v| v.as_usize())
                .unwrap_or(d.default_deadline_ms as usize) as u64,
            prof: j.get("prof").and_then(|v| v.as_bool()).unwrap_or(d.prof),
            prof_interval_ms: j
                .get("prof_interval_ms")
                .and_then(|v| v.as_usize())
                .unwrap_or(d.prof_interval_ms as usize) as u64,
            prof_ring: j.get("prof_ring").and_then(|v| v.as_usize()).unwrap_or(d.prof_ring),
        })
    }
}

/// Options for the multi-model registry (`registry::ModelRegistry`): how
/// many fitted engines one serving process keeps resident, what happens
/// when a load would exceed that bound, and how arriving observations are
/// prequentially scored for the quality/drift surfaces.
#[derive(Clone, Debug, PartialEq)]
pub struct RegistryOptions {
    /// Maximum resident models. A load beyond this either evicts the
    /// least-recently-used non-default model (`lru_evict`) or fails with
    /// a capacity error (HTTP 507).
    pub max_models: usize,
    /// Evict the LRU non-default model to make room instead of rejecting.
    pub lru_evict: bool,
    /// Observed rows a model buffers before the incremental update runs
    /// (1 = every observe request publishes a new generation; larger
    /// values amortize the seam refit across bigger batches). A request
    /// can force either behavior per call (`"buffer"`/`"flush"`).
    pub observe_flush_rows: usize,
    /// After each published generation, rewrite the model's artifact
    /// snapshot in place (only for models loaded from a snapshot path);
    /// untouched blocks reuse their previously encoded bytes.
    pub resnapshot: bool,
    /// How many rows of each drained observe batch the prequential
    /// quality scorer evaluates against the current generation before
    /// `absorb` consumes them (`off` disables every quality surface).
    pub observe_score: ScoreMode,
    /// Sliding-window width (rows) for the rolling RMSE/MNLP/coverage
    /// quality metrics (rounded up to a whole number of buckets).
    pub quality_window: usize,
    /// Drift alarm threshold in nats: `drift_score = windowed MNLP −
    /// fit-time baseline MNLP`; an upward crossing emits one structured
    /// `drift_detected` event.
    pub drift_threshold: f64,
    /// Hard cap on rows a model's observation buffer may hold. An
    /// observe that would exceed it is refused with backpressure
    /// (HTTP 429) instead of growing resident memory without bound.
    pub observe_max_rows: usize,
}

impl Default for RegistryOptions {
    fn default() -> Self {
        RegistryOptions {
            max_models: 8,
            lru_evict: true,
            observe_flush_rows: 1,
            resnapshot: false,
            observe_score: ScoreMode::default(),
            quality_window: 1024,
            drift_threshold: 1.0,
            observe_max_rows: 1 << 20,
        }
    }
}

impl RegistryOptions {
    pub fn validate(&self) -> Result<()> {
        if self.max_models == 0 {
            return Err(PgprError::Config("registry: max_models must be ≥ 1".into()));
        }
        if self.observe_flush_rows == 0 {
            return Err(PgprError::Config("registry: observe_flush_rows must be ≥ 1".into()));
        }
        if self.observe_score != ScoreMode::Off && self.quality_window == 0 {
            return Err(PgprError::Config("registry: quality_window must be ≥ 1".into()));
        }
        if !self.drift_threshold.is_finite() {
            return Err(PgprError::Config("registry: drift_threshold must be finite".into()));
        }
        if self.observe_max_rows == 0 {
            return Err(PgprError::Config("registry: observe_max_rows must be ≥ 1".into()));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("max_models", Json::Num(self.max_models as f64)),
            ("lru_evict", Json::Bool(self.lru_evict)),
            ("observe_flush_rows", Json::Num(self.observe_flush_rows as f64)),
            ("resnapshot", Json::Bool(self.resnapshot)),
            ("observe_score", Json::Str(self.observe_score.selector())),
            ("quality_window", Json::Num(self.quality_window as f64)),
            ("drift_threshold", Json::Num(self.drift_threshold)),
            ("observe_max_rows", Json::Num(self.observe_max_rows as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RegistryOptions> {
        let d = RegistryOptions::default();
        Ok(RegistryOptions {
            max_models: j.get("max_models").and_then(|v| v.as_usize()).unwrap_or(d.max_models),
            lru_evict: j.get("lru_evict").and_then(|v| v.as_bool()).unwrap_or(d.lru_evict),
            observe_flush_rows: j
                .get("observe_flush_rows")
                .and_then(|v| v.as_usize())
                .unwrap_or(d.observe_flush_rows),
            resnapshot: j.get("resnapshot").and_then(|v| v.as_bool()).unwrap_or(d.resnapshot),
            observe_score: match j.get("observe_score").and_then(|v| v.as_str()) {
                Some(s) => ScoreMode::parse(s)?,
                None => d.observe_score,
            },
            quality_window: j
                .get("quality_window")
                .and_then(|v| v.as_usize())
                .unwrap_or(d.quality_window),
            drift_threshold: j
                .get("drift_threshold")
                .and_then(|v| v.as_f64())
                .unwrap_or(d.drift_threshold),
            observe_max_rows: j
                .get("observe_max_rows")
                .and_then(|v| v.as_usize())
                .unwrap_or(d.observe_max_rows),
        })
    }
}

/// Which execution backend runs the parallel LMA protocol (see
/// `cluster::Backend`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Deterministic virtual-time cluster simulator (`cluster::SimCluster`):
    /// rank work executes sequentially, time/traffic are modelled.
    #[default]
    Sim,
    /// Real OS threads (`cluster::ThreadCluster`): each wavefront/summary
    /// task runs on a scoped worker thread. `num_threads = 0` means one
    /// worker per available core.
    Threads { num_threads: usize },
}

impl BackendKind {
    /// Parse a CLI/env selector (case-insensitive): `sim`, `threads`, or
    /// `threads:<n>`.
    pub fn parse(s: &str) -> Result<BackendKind> {
        let t = s.trim().to_ascii_lowercase();
        if t == "sim" {
            return Ok(BackendKind::Sim);
        }
        if t == "threads" {
            return Ok(BackendKind::Threads { num_threads: 0 });
        }
        if let Some(rest) = t.strip_prefix("threads:") {
            let n = rest.parse().map_err(|_| {
                PgprError::Config(format!("bad thread count `{rest}` in backend `{s}`"))
            })?;
            return Ok(BackendKind::Threads { num_threads: n });
        }
        Err(PgprError::Config(format!(
            "unknown backend `{s}` (expected sim | threads | threads:<n>)"
        )))
    }

    /// The CLI selector string this kind parses back from (`sim`,
    /// `threads:<n>`) — used by artifact manifests and `/healthz`.
    pub fn selector(&self) -> String {
        match self {
            BackendKind::Sim => "sim".to_string(),
            BackendKind::Threads { num_threads } => format!("threads:{num_threads}"),
        }
    }

    /// Degree of real parallelism this backend offers (1 for the
    /// simulator, the resolved worker count for threads).
    pub fn parallelism(&self) -> usize {
        match self {
            BackendKind::Sim => 1,
            BackendKind::Threads { num_threads } => {
                crate::util::par::resolve_threads(*num_threads)
            }
        }
    }
}

/// Cluster topology description (machines × cores per machine), matching
/// the paper's experimental platforms, plus the execution backend that
/// runs the protocol.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    pub machines: usize,
    pub cores_per_machine: usize,
    /// One-way latency between cores on the *same* machine (seconds).
    pub intra_latency: f64,
    /// One-way latency between cores on *different* machines (seconds).
    pub inter_latency: f64,
    /// Link bandwidth in bytes/second (gigabit ≈ 1.25e8).
    pub bandwidth: f64,
    /// Execution backend (virtual-time simulator or real threads).
    pub backend: BackendKind,
}

impl ClusterConfig {
    /// Paper's main platform: 32 nodes, gigabit ethernet, simulated.
    pub fn gigabit(machines: usize, cores_per_machine: usize) -> ClusterConfig {
        ClusterConfig {
            machines,
            cores_per_machine,
            intra_latency: 2e-6,  // shared-memory handoff
            inter_latency: 5e-5,  // gigabit + switch hop
            bandwidth: 1.25e8,    // 1 Gbps
            backend: BackendKind::Sim,
        }
    }

    /// Same topology, executed on real OS threads (`num_threads = 0` means
    /// one worker per available core).
    pub fn threads(machines: usize, cores_per_machine: usize, num_threads: usize) -> ClusterConfig {
        ClusterConfig::gigabit(machines, cores_per_machine)
            .with_backend(BackendKind::Threads { num_threads })
    }

    /// Builder-style backend override.
    pub fn with_backend(mut self, backend: BackendKind) -> ClusterConfig {
        self.backend = backend;
        self
    }

    pub fn total_cores(&self) -> usize {
        self.machines * self.cores_per_machine
    }

    pub fn validate(&self) -> Result<()> {
        if self.machines == 0 || self.cores_per_machine == 0 {
            return Err(PgprError::Config("cluster must have ≥1 machine and ≥1 core".into()));
        }
        if self.bandwidth <= 0.0 {
            return Err(PgprError::Config("bandwidth must be positive".into()));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("machines", Json::Num(self.machines as f64)),
            ("cores_per_machine", Json::Num(self.cores_per_machine as f64)),
            ("intra_latency", Json::Num(self.intra_latency)),
            ("inter_latency", Json::Num(self.inter_latency)),
            ("bandwidth", Json::Num(self.bandwidth)),
            ("backend", Json::Str(self.backend.selector())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ClusterConfig> {
        let backend = match j.get("backend").and_then(|v| v.as_str()) {
            Some(s) => BackendKind::parse(s)?,
            None => BackendKind::Sim,
        };
        let num = |field: &'static str| -> Result<f64> {
            j.req(field)?.as_f64().ok_or_else(|| {
                PgprError::Config(format!("cluster field `{field}` must be a number"))
            })
        };
        Ok(ClusterConfig {
            machines: num("machines")? as usize,
            cores_per_machine: num("cores_per_machine")? as usize,
            intra_latency: num("intra_latency")?,
            inter_latency: num("inter_latency")?,
            bandwidth: num("bandwidth")?,
            backend,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lma_config_json_roundtrip() {
        let cfg = LmaConfig {
            num_blocks: 16,
            markov_order: 3,
            support_size: 256,
            seed: 7,
            partition: PartitionStrategy::KMeans { iters: 5 },
            use_pjrt: true,
        };
        let j = cfg.to_json();
        let back = LmaConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn validate_catches_bad_b() {
        let cfg = LmaConfig { num_blocks: 4, markov_order: 4, ..Default::default() };
        assert!(cfg.validate(1000).is_err());
        let ok = LmaConfig { num_blocks: 4, markov_order: 3, ..Default::default() };
        assert!(ok.validate(1000).is_ok());
        assert!(ok.validate(2).is_err()); // fewer points than blocks
    }

    #[test]
    fn cluster_defaults_sane() {
        let c = ClusterConfig::gigabit(32, 2);
        assert_eq!(c.total_cores(), 64);
        assert!(c.validate().is_ok());
        assert!(c.inter_latency > c.intra_latency);
        assert_eq!(c.backend, BackendKind::Sim);
    }

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("sim").unwrap(), BackendKind::Sim);
        assert_eq!(
            BackendKind::parse("threads").unwrap(),
            BackendKind::Threads { num_threads: 0 }
        );
        assert_eq!(
            BackendKind::parse("threads:4").unwrap(),
            BackendKind::Threads { num_threads: 4 }
        );
        assert!(BackendKind::parse("mpi").is_err());
        assert!(BackendKind::parse("threads:x").is_err());
        assert!(BackendKind::parse("threadsgarbage").is_err());
        // Case-insensitive selectors.
        assert_eq!(BackendKind::parse("SIM").unwrap(), BackendKind::Sim);
        assert_eq!(
            BackendKind::parse("Threads:4").unwrap(),
            BackendKind::Threads { num_threads: 4 }
        );
    }

    #[test]
    fn backend_parallelism_resolves() {
        assert_eq!(BackendKind::Sim.parallelism(), 1);
        assert_eq!(BackendKind::Threads { num_threads: 3 }.parallelism(), 3);
        assert!(BackendKind::Threads { num_threads: 0 }.parallelism() >= 1);
        let c = ClusterConfig::threads(2, 2, 4);
        assert_eq!(c.backend, BackendKind::Threads { num_threads: 4 });
        assert_eq!(c.total_cores(), 4);
    }

    #[test]
    fn serve_options_roundtrip_and_validate() {
        let o = ServeOptions {
            listen: "127.0.0.1:0".into(),
            workers: 8,
            batch_size: 32,
            max_delay_us: 500,
            queue_capacity: 64,
            keep_alive: false,
            idle_timeout_ms: 250,
            max_conn_requests: 16,
            f32_u: true,
            trace: false,
            trace_ring: 32,
            slow_request_us: 250_000,
            slo_ms: 40,
            default_deadline_ms: 120,
            prof: false,
            prof_interval_ms: 100,
            prof_ring: 16,
        };
        assert!(o.validate().is_ok());
        let parsed = Json::parse(&o.to_json().to_string()).unwrap();
        let back = ServeOptions::from_json(&parsed).unwrap();
        assert_eq!(back, o);
        // Missing fields fall back to defaults.
        let partial = ServeOptions::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(partial, ServeOptions::default());
        assert!(ServeOptions { workers: 0, ..ServeOptions::default() }.validate().is_err());
        assert!(ServeOptions { batch_size: 0, ..ServeOptions::default() }.validate().is_err());
        assert!(ServeOptions { queue_capacity: 0, ..ServeOptions::default() }
            .validate()
            .is_err());
        // trace_ring 0 is only legal when tracing is off.
        assert!(ServeOptions { trace_ring: 0, ..ServeOptions::default() }.validate().is_err());
        assert!(ServeOptions { trace: false, trace_ring: 0, ..ServeOptions::default() }
            .validate()
            .is_ok());
        // Same shape for the profiler: ring/interval 0 need prof off.
        assert!(ServeOptions { prof_ring: 0, ..ServeOptions::default() }.validate().is_err());
        assert!(ServeOptions { prof_interval_ms: 0, ..ServeOptions::default() }
            .validate()
            .is_err());
        assert!(ServeOptions { prof: false, prof_ring: 0, ..ServeOptions::default() }
            .validate()
            .is_ok());
    }

    #[test]
    fn cluster_config_json_roundtrip() {
        let c = ClusterConfig::gigabit(4, 2).with_backend(BackendKind::Threads { num_threads: 3 });
        let parsed = Json::parse(&c.to_json().to_string()).unwrap();
        let back = ClusterConfig::from_json(&parsed).unwrap();
        assert_eq!(back, c);
        assert_eq!(back.backend.selector(), "threads:3");
        assert_eq!(BackendKind::Sim.selector(), "sim");
    }

    #[test]
    fn registry_options_roundtrip_and_validate() {
        let r = RegistryOptions {
            max_models: 3,
            lru_evict: false,
            observe_flush_rows: 16,
            resnapshot: true,
            observe_score: ScoreMode::All,
            quality_window: 256,
            drift_threshold: 0.5,
            observe_max_rows: 4096,
        };
        assert!(r.validate().is_ok());
        let parsed = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(RegistryOptions::from_json(&parsed).unwrap(), r);
        // Missing fields fall back to defaults.
        let partial = RegistryOptions::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(partial, RegistryOptions::default());
        assert_eq!(partial.observe_score, ScoreMode::Sample(16));
        assert!(RegistryOptions { max_models: 0, ..Default::default() }.validate().is_err());
        assert!(RegistryOptions { observe_flush_rows: 0, ..Default::default() }
            .validate()
            .is_err());
        assert!(RegistryOptions { quality_window: 0, ..Default::default() }.validate().is_err());
        assert!(RegistryOptions {
            quality_window: 0,
            observe_score: ScoreMode::Off,
            ..Default::default()
        }
        .validate()
        .is_ok());
        assert!(RegistryOptions { drift_threshold: f64::NAN, ..Default::default() }
            .validate()
            .is_err());
        assert!(RegistryOptions { observe_max_rows: 0, ..Default::default() }
            .validate()
            .is_err());
        // A bad score-mode selector is an error, not a silent default.
        assert!(RegistryOptions::from_json(&Json::parse("{\"observe_score\":\"half\"}").unwrap())
            .is_err());
    }

    #[test]
    fn serve_options_keepalive_validation() {
        let bad = ServeOptions { keep_alive: true, idle_timeout_ms: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let off = ServeOptions {
            keep_alive: false,
            idle_timeout_ms: 0,
            max_conn_requests: 0,
            ..Default::default()
        };
        assert!(off.validate().is_ok());
    }

    #[test]
    fn partition_kinds_roundtrip() {
        for p in [PartitionStrategy::Contiguous, PartitionStrategy::Random] {
            let cfg = LmaConfig { partition: p.clone(), ..Default::default() };
            let back = LmaConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(back.partition, p);
        }
    }
}
