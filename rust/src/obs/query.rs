//! Minimal query-string parsing shared by the HTTP handlers.
//!
//! One parser for `/predict?trace=1`, `/debug/trace?model=&n=` and
//! `/metrics?format=json` instead of ad-hoc `split('?')` per handler.
//! Zero-copy (borrows the request target); no percent-decoding — the
//! server's query values are plain identifiers and small integers.

/// Split a request target into its path and parsed query.
pub fn parse_query(target: &str) -> (&str, Query<'_>) {
    match target.split_once('?') {
        Some((path, q)) => (path, Query::parse(q)),
        None => (target, Query { params: Vec::new() }),
    }
}

/// Parsed query parameters, in order of appearance.
#[derive(Debug)]
pub struct Query<'a> {
    params: Vec<(&'a str, &'a str)>,
}

impl<'a> Query<'a> {
    fn parse(q: &'a str) -> Query<'a> {
        let params = q
            .split('&')
            .filter(|p| !p.is_empty())
            .map(|p| p.split_once('=').unwrap_or((p, "")))
            .collect();
        Query { params }
    }

    /// The first value for `key`, if present.
    pub fn get(&self, key: &str) -> Option<&'a str> {
        self.params.iter().find(|(k, _)| *k == key).map(|(_, v)| *v)
    }

    /// The first value for `key` parsed as an integer.
    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    /// Boolean switch: present with no value, `1` or `true` ⇒ on;
    /// absent, `0` or `false` (or anything else) ⇒ off.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("" | "1" | "true"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_path_and_params() {
        let (path, q) = parse_query("/debug/trace?model=live&n=16");
        assert_eq!(path, "/debug/trace");
        assert_eq!(q.get("model"), Some("live"));
        assert_eq!(q.get_usize("n"), Some(16));
        assert_eq!(q.get("missing"), None);
    }

    #[test]
    fn no_query_is_empty() {
        let (path, q) = parse_query("/metrics");
        assert_eq!(path, "/metrics");
        assert_eq!(q.get("format"), None);
        assert!(!q.flag("anything"));
    }

    #[test]
    fn flags() {
        let (_, q) = parse_query("/predict?trace=1");
        assert!(q.flag("trace"));
        let (_, q) = parse_query("/predict?trace");
        assert!(q.flag("trace"));
        let (_, q) = parse_query("/predict?trace=true");
        assert!(q.flag("trace"));
        let (_, q) = parse_query("/predict?trace=0");
        assert!(!q.flag("trace"));
        let (_, q) = parse_query("/predict?trace=false");
        assert!(!q.flag("trace"));
    }

    #[test]
    fn odd_shapes_are_tolerated() {
        let (path, q) = parse_query("/p?&&a=1&b&=x&c=");
        assert_eq!(path, "/p");
        assert_eq!(q.get("a"), Some("1"));
        assert_eq!(q.get("b"), Some(""));
        assert_eq!(q.get("c"), Some(""));
        // First occurrence wins.
        let (_, q) = parse_query("/p?k=1&k=2");
        assert_eq!(q.get_usize("k"), Some(1));
    }
}
