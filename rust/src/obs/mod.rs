//! Observability: request-scoped tracing, per-stage latency attribution
//! and structured JSON logging for the serving + online-update pipeline.
//!
//! Everything here is std-only and allocation-light on the hot path:
//!
//! * [`trace`] — the fixed [`Stage`] taxonomy, the [`StageSet`]
//!   per-request stage accumulator (a `Copy` array, no heap), the
//!   [`TraceRing`] per-model ring buffer of completed request traces,
//!   and the process-wide trace-ID counter.
//! * [`log`] — the `PGPR_LOG`-gated structured line logger (one JSON
//!   object per line, one `write_all` per event).
//! * [`query`] — the shared query-string parser used by `/predict`,
//!   `/debug/trace` and `/metrics`.

pub mod log;
pub mod query;
pub mod trace;

pub use log::{log_event, Level};
pub use query::{parse_query, Query};
pub use trace::{next_trace_id, Stage, StageSet, TraceEntry, TraceRing, ALL_STAGES, STAGE_COUNT};
