//! Observability: request-scoped tracing, per-stage latency attribution
//! and structured JSON logging for the serving + online-update pipeline.
//!
//! Everything here is std-only and allocation-light on the hot path:
//!
//! * [`trace`] — the fixed [`Stage`] taxonomy, the [`StageSet`]
//!   per-request stage accumulator (a `Copy` array, no heap), the
//!   [`TraceRing`] per-model ring buffer of completed request traces,
//!   and the process-wide trace-ID counter.
//! * [`log`] — the `PGPR_LOG`-gated structured line logger (one JSON
//!   object per line, one `write_all` per event).
//! * [`query`] — the shared query-string parser used by `/predict`,
//!   `/debug/trace`, `/debug/quality` and `/metrics`.
//! * [`quality`] — prequential model-quality accumulators: the sliding
//!   window of scored observations (rolling RMSE/MNLP/coverage), the
//!   per-block error attribution, and the drift detector against the
//!   fit-time baseline persisted in artifacts.
//! * [`alloc`] — the tracking global allocator (live/peak/throughput
//!   counters, per-subsystem tagged scopes) that binaries opt into with
//!   `#[global_allocator]`.
//! * [`prof`] — per-thread CPU accounting (thread registry + procfs
//!   deltas), the process resource sampler behind `GET /debug/prof`,
//!   and the smoothed CPU-saturation signal the admission gate reads.

pub mod alloc;
pub mod log;
pub mod prof;
pub mod quality;
pub mod query;
pub mod trace;

pub use log::{log_event, Level};
pub use quality::{
    block_of_row, BlockStats, BucketStats, DriftCrossing, ModelQuality, QualityBaseline,
    QualityWindow, ScoreMode, ScoredRow, WindowStats,
};
pub use query::{parse_query, Query};
pub use trace::{next_trace_id, Stage, StageSet, TraceEntry, TraceRing, ALL_STAGES, STAGE_COUNT};
