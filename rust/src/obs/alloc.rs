//! Tracking global allocator with per-subsystem tagged scopes.
//!
//! [`TrackingAlloc`] wraps [`std::alloc::System`] and keeps process-wide
//! heap counters (live bytes, peak, cumulative alloc/dealloc counts,
//! largest single allocation) in relaxed atomics — a handful of
//! uncontended RMWs per allocation, cheap enough to leave on in
//! production binaries. On top of that, a thread-local *tag* attributes
//! every allocation (and deallocation) to the subsystem currently on the
//! stack: wrap a region in `let _g = alloc::scope("predict");` and the
//! per-tag net/throughput/max-single counters name the subsystem when an
//! O(N) copy sneaks back into a hot path.
//!
//! Because `#[global_allocator]` binds per *binary*, the library only
//! exports the wrapper; `rust/src/main.rs`, the benches, and the
//! `obs_prof` integration test each install it themselves. Binaries that
//! don't install it still link this module — every counter just stays at
//! zero and [`tracker_installed`] reports `false`, which is how the
//! `/metrics` heap gauges know to render 0 rather than lie.
//!
//! Deallocations are attributed to the tag active on the *freeing*
//! thread, not the one that allocated — crossing a scope boundary with a
//! live buffer therefore skews two tags' nets by the buffer size while
//! leaving the global counters exact. Scopes that fully contain an
//! allocate→drop cycle balance to zero, which is what the integration
//! test asserts for a fit+predict round.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering::Relaxed};

/// Known scope tags. Index 0 is the default (no scope active); unknown
/// tag names fold into the trailing `"other"` bucket so the allocator
/// never has to allocate to account for an allocation.
pub const TAGS: [&str; 8] =
    ["untagged", "fit", "predict", "absorb", "serialize", "observe", "serve", "other"];
const TAG_COUNT: usize = TAGS.len();
const OTHER: usize = TAG_COUNT - 1;

static INSTALLED: AtomicBool = AtomicBool::new(false);
static LIVE_BYTES: AtomicI64 = AtomicI64::new(0);
static PEAK_BYTES: AtomicU64 = AtomicU64::new(0);
static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);
static DEALLOC_COUNT: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);
static MAX_SINGLE: AtomicU64 = AtomicU64::new(0);

static TAG_NET: [AtomicI64; TAG_COUNT] = [const { AtomicI64::new(0) }; TAG_COUNT];
static TAG_ALLOC_BYTES: [AtomicU64; TAG_COUNT] = [const { AtomicU64::new(0) }; TAG_COUNT];
static TAG_ALLOCS: [AtomicU64; TAG_COUNT] = [const { AtomicU64::new(0) }; TAG_COUNT];
static TAG_MAX_SINGLE: [AtomicU64; TAG_COUNT] = [const { AtomicU64::new(0) }; TAG_COUNT];

thread_local! {
    static CUR_TAG: Cell<usize> = const { Cell::new(0) };
}

/// Resolve a tag name to its fixed slot (unknown → `"other"`).
fn tag_index(tag: &str) -> usize {
    TAGS.iter().position(|t| *t == tag).unwrap_or(OTHER)
}

/// Tag active on the calling thread. `try_with` keeps this safe during
/// thread-local teardown (allocations after TLS destruction fold into
/// `untagged`).
#[inline]
fn current_tag() -> usize {
    CUR_TAG.try_with(|c| c.get()).unwrap_or(0)
}

/// Enter a tagged allocation scope on this thread; the previous tag is
/// restored when the guard drops, so scopes nest.
pub fn scope(tag: &str) -> ScopeGuard {
    let idx = tag_index(tag);
    let prev = CUR_TAG.try_with(|c| c.replace(idx)).unwrap_or(0);
    ScopeGuard { prev }
}

/// RAII guard returned by [`scope`].
pub struct ScopeGuard {
    prev: usize,
}

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        let _ = CUR_TAG.try_with(|c| c.set(self.prev));
    }
}

#[inline]
fn note_alloc(size: usize) {
    let sz = size as u64;
    ALLOC_COUNT.fetch_add(1, Relaxed);
    ALLOC_BYTES.fetch_add(sz, Relaxed);
    MAX_SINGLE.fetch_max(sz, Relaxed);
    let live = LIVE_BYTES.fetch_add(size as i64, Relaxed) + size as i64;
    if live > 0 {
        PEAK_BYTES.fetch_max(live as u64, Relaxed);
    }
    let tag = current_tag();
    TAG_NET[tag].fetch_add(size as i64, Relaxed);
    TAG_ALLOC_BYTES[tag].fetch_add(sz, Relaxed);
    TAG_ALLOCS[tag].fetch_add(1, Relaxed);
    TAG_MAX_SINGLE[tag].fetch_max(sz, Relaxed);
}

#[inline]
fn note_dealloc(size: usize) {
    DEALLOC_COUNT.fetch_add(1, Relaxed);
    LIVE_BYTES.fetch_sub(size as i64, Relaxed);
    TAG_NET[current_tag()].fetch_sub(size as i64, Relaxed);
}

/// The wrapper allocator. Install per binary with
/// `#[global_allocator] static A: pgpr::obs::alloc::TrackingAlloc = pgpr::obs::alloc::TrackingAlloc;`
pub struct TrackingAlloc;

// SAFETY: defers every allocation verbatim to `System`; the bookkeeping
// touches only atomics and a thread-local `Cell`, neither of which can
// allocate or re-enter the allocator.
unsafe impl GlobalAlloc for TrackingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if !INSTALLED.load(Relaxed) {
            INSTALLED.store(true, Relaxed);
        }
        let p = unsafe { System.alloc(layout) };
        if !p.is_null() {
            note_alloc(layout.size());
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if !INSTALLED.load(Relaxed) {
            INSTALLED.store(true, Relaxed);
        }
        let p = unsafe { System.alloc_zeroed(layout) };
        if !p.is_null() {
            note_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) };
        note_dealloc(layout.size());
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = unsafe { System.realloc(ptr, layout, new_size) };
        if !p.is_null() {
            note_dealloc(layout.size());
            note_alloc(new_size);
        }
        p
    }
}

/// Whether a [`TrackingAlloc`] is the active global allocator in this
/// binary (set by its first allocation, i.e. before `main`).
pub fn tracker_installed() -> bool {
    INSTALLED.load(Relaxed)
}

/// Point-in-time view of the process-wide heap counters.
#[derive(Clone, Copy, Debug)]
pub struct AllocSnapshot {
    /// Bytes currently allocated and not yet freed.
    pub live_bytes: i64,
    /// High-water mark of `live_bytes`.
    pub peak_bytes: u64,
    /// Cumulative allocation calls (alloc + alloc_zeroed + realloc grows).
    pub alloc_count: u64,
    /// Cumulative deallocation calls.
    pub dealloc_count: u64,
    /// Cumulative bytes requested across all allocations.
    pub alloc_bytes: u64,
    /// Largest single allocation since process start or [`reset_max_single`].
    pub max_single: u64,
}

/// Read the global counters (all relaxed; a consistent-enough snapshot
/// for observability, not a linearizable one).
pub fn snapshot() -> AllocSnapshot {
    AllocSnapshot {
        live_bytes: LIVE_BYTES.load(Relaxed),
        peak_bytes: PEAK_BYTES.load(Relaxed),
        alloc_count: ALLOC_COUNT.load(Relaxed),
        dealloc_count: DEALLOC_COUNT.load(Relaxed),
        alloc_bytes: ALLOC_BYTES.load(Relaxed),
        max_single: MAX_SINGLE.load(Relaxed),
    }
}

/// Per-tag heap attribution.
#[derive(Clone, Debug)]
pub struct TagStats {
    /// Tag name from [`TAGS`].
    pub tag: &'static str,
    /// Net bytes (allocs − frees) attributed to this tag.
    pub net_bytes: i64,
    /// Cumulative bytes allocated under this tag.
    pub alloc_bytes: u64,
    /// Cumulative allocation calls under this tag.
    pub allocs: u64,
    /// Largest single allocation under this tag since start/reset.
    pub max_single: u64,
}

/// Stats for one named tag (unknown names read the `"other"` bucket).
pub fn tag_stats(tag: &str) -> TagStats {
    let i = tag_index(tag);
    TagStats {
        tag: TAGS[i],
        net_bytes: TAG_NET[i].load(Relaxed),
        alloc_bytes: TAG_ALLOC_BYTES[i].load(Relaxed),
        allocs: TAG_ALLOCS[i].load(Relaxed),
        max_single: TAG_MAX_SINGLE[i].load(Relaxed),
    }
}

/// All tags that have seen any traffic (plus `untagged` always), for
/// the `/debug/prof` breakdown.
pub fn tag_breakdown() -> Vec<TagStats> {
    (0..TAG_COUNT)
        .map(|i| tag_stats(TAGS[i]))
        .filter(|s| s.tag == "untagged" || s.allocs > 0)
        .collect()
}

/// Zero the global and per-tag max-single-allocation watermarks so a
/// bench can measure a steady-state window in isolation.
pub fn reset_max_single() {
    MAX_SINGLE.store(0, Relaxed);
    for m in &TAG_MAX_SINGLE {
        m.store(0, Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tag_index_resolves_known_and_folds_unknown() {
        assert_eq!(tag_index("untagged"), 0);
        assert_eq!(tag_index("predict"), 2);
        assert_eq!(tag_index("no-such-tag"), OTHER);
        assert_eq!(tag_stats("no-such-tag").tag, "other");
    }

    #[test]
    fn scopes_nest_and_restore() {
        assert_eq!(current_tag(), 0);
        {
            let _a = scope("fit");
            assert_eq!(current_tag(), tag_index("fit"));
            {
                let _b = scope("predict");
                assert_eq!(current_tag(), tag_index("predict"));
            }
            assert_eq!(current_tag(), tag_index("fit"));
        }
        assert_eq!(current_tag(), 0);
    }

    #[test]
    fn counters_move_when_noted() {
        // The lib test binary does not install the allocator, so drive
        // the bookkeeping directly.
        let before = snapshot();
        let t0 = tag_stats("fit");
        {
            let _g = scope("fit");
            note_alloc(1024);
            note_dealloc(1024);
        }
        let after = snapshot();
        let t1 = tag_stats("fit");
        assert!(after.alloc_count >= before.alloc_count + 1);
        assert!(after.dealloc_count >= before.dealloc_count + 1);
        assert!(after.alloc_bytes >= before.alloc_bytes + 1024);
        assert_eq!(t1.net_bytes, t0.net_bytes);
        assert!(t1.alloc_bytes >= t0.alloc_bytes + 1024);
        assert!(t1.max_single >= 1024);
    }
}
