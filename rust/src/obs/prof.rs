//! Continuous resource profiling: per-thread CPU accounting, process
//! memory/fd sampling, and the fixed-size profile ring behind
//! `GET /debug/prof`.
//!
//! Everything here is std-only and `libc`-free. On Linux the numbers
//! come straight from procfs — `/proc/self/status` (VmRSS/VmHWM),
//! `/proc/self/stat` + `/proc/self/task/<tid>/stat` (utime+stime), and
//! `/proc/self/fd` (open descriptors). Tick→seconds conversion assumes
//! `USER_HZ = 100`, which has been the value on every mainstream Linux
//! ABI for decades (reading it portably needs `sysconf`, i.e. libc).
//! On other platforms every probe degrades to `None`/empty and the
//! sampler records zeros — the serving stack works identically, it just
//! has nothing to report.
//!
//! Three cooperating pieces:
//!
//! * a **thread registry**: long-lived threads (HTTP workers, the
//!   acceptor, per-model batchers, `util::par` chunk workers, the
//!   sampler itself) register human-readable names via
//!   [`register_thread`]; the guard folds the thread's final CPU total
//!   into a retired-by-name accumulator on drop, so
//!   `pgpr_thread_cpu_seconds_total{thread=...}` stays monotone per
//!   name across pool respawns and short-lived workers are not lost;
//! * a **sampler thread** ([`start_sampler`], one per server, named
//!   `pgpr-prof`) that snapshots per-thread utilization, RSS/VmHWM, fd
//!   and connection counts, and the [`super::alloc`] tracker state into
//!   a [`SampleRing`] (same per-slot-Mutex + atomic-head shape as
//!   `obs::trace::TraceRing`), and maintains the smoothed process CPU
//!   saturation the admission gate reads;
//! * module-level gauges that work with or without a sampler: the
//!   [`track_connection`] RAII guard behind `pgpr_open_connections`,
//!   and [`cpu_saturation`] (0.0 when no sampler has ever run, so
//!   nothing can cpu-shed in configurations that never profile).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::obs::alloc;
use crate::util::fault;

/// Clock ticks per second for `/proc` utime/stime fields (see module docs).
const USER_HZ: f64 = 100.0;

/// EWMA weight for the newest saturation observation.
const SATURATION_ALPHA: f64 = 0.3;

/// Saturation at or above which the admission gate starts shedding with
/// reason `cpu` (given a real backlog; see `server::admission`).
pub const CPU_SHED_THRESHOLD: f64 = 0.95;

// ---------------------------------------------------------------------------
// procfs probes
// ---------------------------------------------------------------------------

/// Kernel thread id of the calling thread (Linux; `None` elsewhere).
#[cfg(target_os = "linux")]
pub fn current_tid() -> Option<u64> {
    let link = std::fs::read_link("/proc/thread-self").ok()?;
    link.file_name()?.to_str()?.parse().ok()
}

/// Kernel thread id of the calling thread (Linux; `None` elsewhere).
#[cfg(not(target_os = "linux"))]
pub fn current_tid() -> Option<u64> {
    None
}

/// Parse utime+stime (seconds) out of a `/proc/.../stat` line. The comm
/// field is parenthesized and may itself contain spaces or parentheses,
/// so fields are located after the *last* `)`.
fn parse_stat_cpu(stat: &str) -> Option<f64> {
    let rest = stat.rsplit_once(')')?.1;
    let mut it = rest.split_whitespace();
    // After the comm: state is overall field 3, utime/stime are 14/15.
    let utime: f64 = it.nth(11)?.parse().ok()?;
    let stime: f64 = it.next()?.parse().ok()?;
    Some((utime + stime) / USER_HZ)
}

/// Thread name (comm) out of a `/proc/.../stat` line.
fn parse_stat_comm(stat: &str) -> Option<&str> {
    let open = stat.find('(')?;
    let close = stat.rfind(')')?;
    stat.get(open + 1..close)
}

/// Cumulative CPU seconds of one thread (Linux; `None` elsewhere).
#[cfg(target_os = "linux")]
pub fn thread_cpu_seconds(tid: u64) -> Option<f64> {
    let stat = std::fs::read_to_string(format!("/proc/self/task/{tid}/stat")).ok()?;
    parse_stat_cpu(&stat)
}

/// Cumulative CPU seconds of one thread (Linux; `None` elsewhere).
#[cfg(not(target_os = "linux"))]
pub fn thread_cpu_seconds(_tid: u64) -> Option<f64> {
    None
}

/// Cumulative process CPU seconds, including already-exited threads
/// (Linux; `None` elsewhere).
#[cfg(target_os = "linux")]
pub fn process_cpu_seconds() -> Option<f64> {
    let stat = std::fs::read_to_string("/proc/self/stat").ok()?;
    parse_stat_cpu(&stat)
}

/// Cumulative process CPU seconds (Linux; `None` elsewhere).
#[cfg(not(target_os = "linux"))]
pub fn process_cpu_seconds() -> Option<f64> {
    None
}

/// One `Vm*:  <n> kB` value from `/proc/self/status`, in bytes.
fn parse_status_kb(status: &str, key: &str) -> Option<u64> {
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix(key) {
            let kb: u64 = rest.trim_start_matches(':').split_whitespace().next()?.parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Resident set size and its high-water mark, in bytes.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemInfo {
    /// Current resident set size (VmRSS).
    pub rss_bytes: u64,
    /// Peak resident set size (VmHWM).
    pub hwm_bytes: u64,
}

/// Process memory numbers (Linux; `None` elsewhere).
#[cfg(target_os = "linux")]
pub fn memory_info() -> Option<MemInfo> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    Some(MemInfo {
        rss_bytes: parse_status_kb(&status, "VmRSS")?,
        hwm_bytes: parse_status_kb(&status, "VmHWM").unwrap_or(0),
    })
}

/// Process memory numbers (Linux; `None` elsewhere).
#[cfg(not(target_os = "linux"))]
pub fn memory_info() -> Option<MemInfo> {
    None
}

/// Open file descriptor count (includes the descriptor the probe itself
/// holds while listing; Linux, `None` elsewhere).
#[cfg(target_os = "linux")]
pub fn open_fds() -> Option<u64> {
    Some(std::fs::read_dir("/proc/self/fd").ok()?.count() as u64)
}

/// Open file descriptor count (Linux; `None` elsewhere).
#[cfg(not(target_os = "linux"))]
pub fn open_fds() -> Option<u64> {
    None
}

// ---------------------------------------------------------------------------
// Thread registry
// ---------------------------------------------------------------------------

#[derive(Default)]
struct RegistryInner {
    /// Live registered threads: tid → display name.
    names: HashMap<u64, String>,
    /// CPU seconds of exited registered threads, accumulated per name so
    /// the exported counter stays monotone across respawns.
    retired: HashMap<String, f64>,
}

fn registry() -> &'static Mutex<RegistryInner> {
    static REG: OnceLock<Mutex<RegistryInner>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(RegistryInner::default()))
}

fn lock_registry() -> std::sync::MutexGuard<'static, RegistryInner> {
    registry().lock().unwrap_or_else(|e| e.into_inner())
}

/// Register the calling thread under a human-readable name until the
/// returned guard drops. Drop the guard on the same thread (it reads the
/// thread's own final CPU total to retire it).
pub fn register_thread(name: &str) -> ThreadGuard {
    match current_tid() {
        Some(tid) => {
            lock_registry().names.insert(tid, name.to_string());
            ThreadGuard { tid: Some(tid) }
        }
        None => ThreadGuard { tid: None },
    }
}

/// RAII registration returned by [`register_thread`].
pub struct ThreadGuard {
    tid: Option<u64>,
}

impl Drop for ThreadGuard {
    fn drop(&mut self) {
        if let Some(tid) = self.tid {
            let cpu = thread_cpu_seconds(tid).unwrap_or(0.0);
            let mut reg = lock_registry();
            if let Some(name) = reg.names.remove(&tid) {
                *reg.retired.entry(name).or_insert(0.0) += cpu;
            }
        }
    }
}

/// Escape a thread name for use as a Prometheus label value.
pub fn label_escape(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for c in name.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Cumulative CPU seconds per thread name: every live task in
/// `/proc/self/task` (registered names take precedence over the kernel
/// comm) plus the retired accumulator, merged by name and sorted.
/// Empty off-Linux.
pub fn thread_cpu_totals() -> Vec<(String, f64)> {
    let mut totals: HashMap<String, f64> = {
        let reg = lock_registry();
        reg.retired.clone()
    };
    #[cfg(target_os = "linux")]
    if let Ok(dir) = std::fs::read_dir("/proc/self/task") {
        let names: HashMap<u64, String> = lock_registry().names.clone();
        for entry in dir.flatten() {
            let Some(tid) = entry.file_name().to_str().and_then(|s| s.parse::<u64>().ok())
            else {
                continue;
            };
            let Ok(stat) = std::fs::read_to_string(format!("/proc/self/task/{tid}/stat")) else {
                continue;
            };
            let Some(cpu) = parse_stat_cpu(&stat) else { continue };
            let name = names
                .get(&tid)
                .cloned()
                .or_else(|| parse_stat_comm(&stat).map(|c| c.to_string()))
                .unwrap_or_else(|| format!("tid-{tid}"));
            *totals.entry(name).or_insert(0.0) += cpu;
        }
    }
    let mut v: Vec<(String, f64)> = totals.into_iter().collect();
    v.sort_by(|a, b| a.0.cmp(&b.0));
    v
}

// ---------------------------------------------------------------------------
// Connection gauge
// ---------------------------------------------------------------------------

static OPEN_CONNECTIONS: AtomicI64 = AtomicI64::new(0);

/// Track one accepted connection for the lifetime of the guard.
pub fn track_connection() -> ConnGuard {
    OPEN_CONNECTIONS.fetch_add(1, Relaxed);
    ConnGuard(())
}

/// RAII connection count returned by [`track_connection`].
pub struct ConnGuard(());

impl Drop for ConnGuard {
    fn drop(&mut self) {
        OPEN_CONNECTIONS.fetch_sub(1, Relaxed);
    }
}

/// Connections currently open across every server in this process.
pub fn open_connections() -> i64 {
    OPEN_CONNECTIONS.load(Relaxed).max(0)
}

// ---------------------------------------------------------------------------
// Samples and the profile ring
// ---------------------------------------------------------------------------

/// One thread's share of a [`ProfSample`].
#[derive(Clone, Debug)]
pub struct ThreadSample {
    /// Display name (registry name, else kernel comm).
    pub name: String,
    /// Cumulative CPU seconds for this name (live + retired).
    pub cpu_s: f64,
    /// Fraction of one core used since the previous sample (0 on the
    /// first sample for a name).
    pub util: f64,
}

/// One snapshot taken by the sampler thread.
#[derive(Clone, Debug)]
pub struct ProfSample {
    /// Seconds since server start at the moment of the sample.
    pub uptime_s: f64,
    /// Resident set size, bytes (0 off-Linux).
    pub rss_bytes: u64,
    /// Peak resident set size, bytes (0 off-Linux).
    pub hwm_bytes: u64,
    /// Open file descriptors (0 off-Linux).
    pub open_fds: u64,
    /// Open HTTP connections (process-wide gauge).
    pub open_connections: i64,
    /// Tracking-allocator live bytes (0 when the tracker isn't installed).
    pub heap_live_bytes: i64,
    /// Tracking-allocator peak bytes.
    pub heap_peak_bytes: u64,
    /// Cumulative process CPU seconds.
    pub process_cpu_s: f64,
    /// Smoothed process CPU saturation in [0, 1] as of this sample.
    pub cpu_saturation: f64,
    /// Per-name thread CPU totals and interval utilization.
    pub threads: Vec<ThreadSample>,
}

/// Fixed-size ring of [`ProfSample`]s — same shape as
/// `obs::trace::TraceRing`: per-slot `Mutex` + one atomic head, so the
/// sampler never blocks readers for more than one slot.
pub struct SampleRing {
    slots: Vec<Mutex<Option<ProfSample>>>,
    head: AtomicU64,
}

impl SampleRing {
    /// Ring with room for `capacity` samples (0 = inert).
    pub fn new(capacity: usize) -> SampleRing {
        SampleRing {
            slots: (0..capacity).map(|_| Mutex::new(None)).collect(),
            head: AtomicU64::new(0),
        }
    }

    /// Slot count.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Samples currently held (saturates at capacity).
    pub fn len(&self) -> usize {
        (self.head.load(Relaxed) as usize).min(self.slots.len())
    }

    /// Whether no sample has been pushed yet.
    pub fn is_empty(&self) -> bool {
        self.head.load(Relaxed) == 0 || self.slots.is_empty()
    }

    /// Append a sample, overwriting the oldest once full.
    pub fn push(&self, sample: ProfSample) {
        if self.slots.is_empty() {
            return;
        }
        let seq = self.head.fetch_add(1, Relaxed) as usize;
        let slot = seq % self.slots.len();
        *self.slots[slot].lock().unwrap_or_else(|e| e.into_inner()) = Some(sample);
    }

    /// Up to `n` most recent samples, newest first.
    pub fn last(&self, n: usize) -> Vec<ProfSample> {
        let cap = self.slots.len();
        if cap == 0 {
            return Vec::new();
        }
        let head = self.head.load(Relaxed) as usize;
        let take = n.min(cap).min(head);
        let mut out = Vec::with_capacity(take);
        for k in 0..take {
            let idx = (head - 1 - k) % cap;
            if let Some(s) = self.slots[idx].lock().unwrap_or_else(|e| e.into_inner()).clone() {
                out.push(s);
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// CPU saturation signal
// ---------------------------------------------------------------------------

/// f64 bits of the EWMA-smoothed saturation (written by samplers).
static SATURATION_BITS: AtomicU64 = AtomicU64::new(0);
/// Number of sampler threads currently running in this process.
static SAMPLERS: AtomicUsize = AtomicUsize::new(0);
/// EWMA observations recorded so far (saturation deltas, not samples).
static SATURATION_OBS: AtomicU64 = AtomicU64::new(0);

/// EWMA observations before [`cpu_saturation`] reports a live value:
/// the gate never sheds on a signal it has barely measured (a busy but
/// short-lived server — e.g. a test booting under a parallel build —
/// must not look saturated off one hot interval).
const SATURATION_WARMUP: u64 = 5;

/// Smoothed process CPU saturation in [0, 1]. The fault point
/// `cpu_saturation_pct` overrides it for deterministic overload tests;
/// without that the value is the sampler's EWMA once it has at least
/// [`SATURATION_WARMUP`] observations, and 0.0 otherwise — so servers
/// that never profile (or barely started) can never cpu-shed.
pub fn cpu_saturation() -> f64 {
    if let Some(pct) = fault::peek(fault::CPU_SATURATION_PCT) {
        return pct as f64 / 100.0;
    }
    if SAMPLERS.load(Relaxed) == 0 || SATURATION_OBS.load(Relaxed) < SATURATION_WARMUP {
        return 0.0;
    }
    f64::from_bits(SATURATION_BITS.load(Relaxed))
}

/// Sampler threads currently running in this process.
pub fn active_samplers() -> usize {
    SAMPLERS.load(Relaxed)
}

// ---------------------------------------------------------------------------
// The sampler thread
// ---------------------------------------------------------------------------

/// Handle to a running sampler; stops and joins the thread on
/// [`Sampler::shutdown`] or drop.
pub struct Sampler {
    stop: Arc<AtomicBool>,
    ring: Arc<SampleRing>,
    join: Option<JoinHandle<()>>,
}

impl Sampler {
    /// The ring the sampler writes into.
    pub fn ring(&self) -> Arc<SampleRing> {
        Arc::clone(&self.ring)
    }

    /// Stop the sampler and join its thread (idempotent).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Relaxed);
        if let Some(join) = self.join.take() {
            join.thread().unpark();
            let _ = join.join();
        }
    }
}

impl Drop for Sampler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Start a background sampler: one snapshot immediately, then one per
/// `interval`, into a fresh ring of `ring_capacity` slots. `start` is
/// the server's start instant (for `uptime_s`).
pub fn start_sampler(
    interval: Duration,
    ring_capacity: usize,
    start: Instant,
) -> std::io::Result<Sampler> {
    let stop = Arc::new(AtomicBool::new(false));
    let ring = Arc::new(SampleRing::new(ring_capacity.max(1)));
    let stop_thread = Arc::clone(&stop);
    let ring_thread = Arc::clone(&ring);
    let join = std::thread::Builder::new().name("pgpr-prof".into()).spawn(move || {
        let _reg = register_thread("prof");
        SAMPLERS.fetch_add(1, Relaxed);
        let mut prev_proc: Option<(Instant, f64)> = None;
        let mut prev_threads: HashMap<String, f64> = HashMap::new();
        while !stop_thread.load(Relaxed) {
            let sample = take_sample(start, &mut prev_proc, &mut prev_threads);
            ring_thread.push(sample);
            std::thread::park_timeout(interval);
        }
        SAMPLERS.fetch_sub(1, Relaxed);
    })?;
    Ok(Sampler { stop, ring, join: Some(join) })
}

/// Take one snapshot and advance the saturation EWMA.
fn take_sample(
    start: Instant,
    prev_proc: &mut Option<(Instant, f64)>,
    prev_threads: &mut HashMap<String, f64>,
) -> ProfSample {
    let now = Instant::now();
    let proc_cpu = process_cpu_seconds().unwrap_or(0.0);
    let wall = prev_proc.map(|(t0, _)| now.duration_since(t0).as_secs_f64()).unwrap_or(0.0);
    if let Some((_, c0)) = *prev_proc {
        if wall > 0.0 {
            let cores = crate::util::par::available_cores().max(1) as f64;
            let inst = ((proc_cpu - c0) / (wall * cores)).clamp(0.0, 1.0);
            let old = f64::from_bits(SATURATION_BITS.load(Relaxed));
            let new = if old > 0.0 {
                SATURATION_ALPHA * inst + (1.0 - SATURATION_ALPHA) * old
            } else {
                inst
            };
            SATURATION_BITS.store(new.to_bits(), Relaxed);
            SATURATION_OBS.fetch_add(1, Relaxed);
        }
    }
    *prev_proc = Some((now, proc_cpu));

    let totals = thread_cpu_totals();
    let threads: Vec<ThreadSample> = totals
        .into_iter()
        .map(|(name, cpu_s)| {
            let util = match prev_threads.get(&name) {
                Some(&c0) if wall > 0.0 => ((cpu_s - c0) / wall).max(0.0),
                _ => 0.0,
            };
            ThreadSample { name, cpu_s, util }
        })
        .collect();
    prev_threads.clear();
    for t in &threads {
        prev_threads.insert(t.name.clone(), t.cpu_s);
    }

    let mem = memory_info().unwrap_or_default();
    let heap = alloc::snapshot();
    ProfSample {
        uptime_s: now.duration_since(start).as_secs_f64(),
        rss_bytes: mem.rss_bytes,
        hwm_bytes: mem.hwm_bytes,
        open_fds: open_fds().unwrap_or(0),
        open_connections: open_connections(),
        heap_live_bytes: heap.live_bytes,
        heap_peak_bytes: heap.peak_bytes,
        process_cpu_s: proc_cpu,
        cpu_saturation: cpu_saturation(),
        threads,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stat_cpu_parses_after_last_paren() {
        // comm containing spaces and a ')' must not shift the fields:
        // after the last ')' the tokens are state.. with utime=300,
        // stime=50 at overall fields 14/15.
        let stat = "1234 (pgpr ) srv) S 1 2 3 4 5 6 7 8 9 10 300 50 0 0 20 0 8 0 100";
        let cpu = parse_stat_cpu(stat).expect("parses");
        assert!((cpu - 3.5).abs() < 1e-12, "300+50 ticks at 100Hz = 3.5s, got {cpu}");
        assert_eq!(parse_stat_comm(stat), Some("pgpr ) srv"));
        assert_eq!(parse_stat_cpu("garbage"), None);
    }

    #[test]
    fn status_kb_parses_vm_lines() {
        let status = "Name:\tpgpr\nVmPeak:\t  200 kB\nVmRSS:\t    84 kB\nVmHWM:\t   96 kB\n";
        assert_eq!(parse_status_kb(status, "VmRSS"), Some(84 * 1024));
        assert_eq!(parse_status_kb(status, "VmHWM"), Some(96 * 1024));
        assert_eq!(parse_status_kb(status, "VmSwap"), None);
    }

    fn sample(i: usize) -> ProfSample {
        ProfSample {
            uptime_s: i as f64,
            rss_bytes: 0,
            hwm_bytes: 0,
            open_fds: 0,
            open_connections: 0,
            heap_live_bytes: 0,
            heap_peak_bytes: 0,
            process_cpu_s: 0.0,
            cpu_saturation: 0.0,
            threads: Vec::new(),
        }
    }

    #[test]
    fn ring_wraps_and_returns_newest_first() {
        let ring = SampleRing::new(4);
        assert!(ring.is_empty());
        for i in 0..6 {
            ring.push(sample(i));
        }
        assert_eq!(ring.len(), 4);
        let got: Vec<f64> = ring.last(10).iter().map(|s| s.uptime_s).collect();
        assert_eq!(got, vec![5.0, 4.0, 3.0, 2.0]);
        let got: Vec<f64> = ring.last(2).iter().map(|s| s.uptime_s).collect();
        assert_eq!(got, vec![5.0, 4.0]);
    }

    #[test]
    fn zero_capacity_ring_is_inert() {
        let ring = SampleRing::new(0);
        ring.push(sample(1));
        assert!(ring.is_empty());
        assert!(ring.last(5).is_empty());
    }

    #[test]
    fn registry_retires_names_monotonically() {
        let name = "prof-test-worker";
        let before: f64 = thread_cpu_totals()
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, c)| *c)
            .sum();
        let handle = std::thread::spawn(move || {
            let _g = register_thread(name);
            // Burn a little CPU so the retirement fold has something.
            let mut acc = 0u64;
            for i in 0..2_000_000u64 {
                acc = acc.wrapping_add(i ^ acc);
            }
            assert!(acc != 1); // keep the loop observable
        });
        handle.join().unwrap();
        if current_tid().is_some() {
            let after: f64 = thread_cpu_totals()
                .iter()
                .filter(|(n, _)| n == name)
                .map(|(_, c)| *c)
                .sum();
            assert!(after >= before, "retired CPU accumulator must be monotone");
        }
    }

    #[test]
    fn label_escape_handles_specials() {
        assert_eq!(label_escape("plain"), "plain");
        assert_eq!(label_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn saturation_absent_without_sampler_or_fault() {
        let _g = fault::serial_guard();
        fault::reset();
        if active_samplers() == 0 {
            assert_eq!(cpu_saturation(), 0.0);
        }
        fault::arm(fault::CPU_SATURATION_PCT, 100);
        assert!((cpu_saturation() - 1.0).abs() < 1e-12);
        fault::reset();
    }
}
