//! Structured JSON line logger, gated by the `PGPR_LOG` environment
//! variable.
//!
//! Each event is one JSON object on one line, written to stderr with a
//! single `write_all` so concurrent threads never interleave mid-line:
//!
//! ```text
//! {"ts_ms":1765432100123,"level":"info","event":"model_loaded","model":"live","generation":1}
//! ```
//!
//! Levels: `PGPR_LOG=off|info|debug` (default `info`). `debug` adds a
//! per-request event on the predict path; `off` silences everything,
//! which the latency bench uses to measure the logger's cost envelope.

use std::io::Write;
use std::sync::OnceLock;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::json::Json;

/// Log severity, ordered: `Off < Info < Debug`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Off,
    Info,
    Debug,
}

impl Level {
    /// Parse a `PGPR_LOG` value; unknown values fall back to `Info` so a
    /// typo never silences the log.
    pub fn parse(s: &str) -> Level {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "none" | "0" => Level::Off,
            "debug" | "2" => Level::Debug,
            _ => Level::Info,
        }
    }

    fn name(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

static LEVEL: OnceLock<Level> = OnceLock::new();

/// The configured level (reads `PGPR_LOG` once; default `Info`).
pub fn level() -> Level {
    *LEVEL.get_or_init(|| match std::env::var("PGPR_LOG") {
        Ok(v) => Level::parse(&v),
        Err(_) => Level::Info,
    })
}

/// Whether an event at `at` passes the configured gate. Pure so the
/// gating truth table is unit-testable without touching the env.
pub fn gate(at: Level, configured: Level) -> bool {
    at != Level::Off && configured >= at
}

/// Whether an event at `at` would be emitted under the process config.
pub fn enabled(at: Level) -> bool {
    gate(at, level())
}

/// Serialize one event line into `w` (the testable core of
/// [`log_event`]): `ts_ms` + `level` + `event` then the caller's fields.
pub fn write_event_to<W: Write>(
    w: &mut W,
    at: Level,
    event: &str,
    fields: Vec<(&str, Json)>,
) -> std::io::Result<()> {
    let ts_ms = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0);
    let mut all: Vec<(&str, Json)> = Vec::with_capacity(fields.len() + 3);
    all.push(("ts_ms", Json::Num(ts_ms as f64)));
    all.push(("level", Json::Str(at.name().into())));
    all.push(("event", Json::Str(event.into())));
    all.extend(fields);
    let mut line = Json::obj(all).to_string();
    line.push('\n');
    w.write_all(line.as_bytes())
}

/// Emit a structured event to stderr if the level gate passes. The line
/// is built off-lock and written with one `write_all`; write errors are
/// swallowed (logging must never take down the serving path).
pub fn log_event(at: Level, event: &str, fields: Vec<(&str, Json)>) {
    if !enabled(at) {
        return;
    }
    let stderr = std::io::stderr();
    let mut lock = stderr.lock();
    let _ = write_event_to(&mut lock, at, event, fields);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::parse("off"), Level::Off);
        assert_eq!(Level::parse("NONE"), Level::Off);
        assert_eq!(Level::parse("0"), Level::Off);
        assert_eq!(Level::parse("info"), Level::Info);
        assert_eq!(Level::parse("1"), Level::Info);
        assert_eq!(Level::parse("Debug"), Level::Debug);
        assert_eq!(Level::parse("2"), Level::Debug);
        // Unknown values keep the default rather than going silent.
        assert_eq!(Level::parse("verbose"), Level::Info);
    }

    #[test]
    fn gate_truth_table() {
        // configured = Off silences everything.
        assert!(!gate(Level::Info, Level::Off));
        assert!(!gate(Level::Debug, Level::Off));
        // configured = Info passes info, drops debug.
        assert!(gate(Level::Info, Level::Info));
        assert!(!gate(Level::Debug, Level::Info));
        // configured = Debug passes both.
        assert!(gate(Level::Info, Level::Debug));
        assert!(gate(Level::Debug, Level::Debug));
        // An event can never be logged "at Off".
        assert!(!gate(Level::Off, Level::Debug));
    }

    #[test]
    fn event_line_is_one_json_object() {
        let mut buf = Vec::new();
        write_event_to(
            &mut buf,
            Level::Info,
            "model_loaded",
            vec![
                ("model", Json::Str("live".into())),
                ("generation", Json::Num(3.0)),
            ],
        )
        .unwrap();
        let line = String::from_utf8(buf).unwrap();
        assert!(line.ends_with('\n'));
        let parsed = Json::parse(line.trim()).unwrap();
        assert_eq!(parsed.get("event").and_then(|v| v.as_str()), Some("model_loaded"));
        assert_eq!(parsed.get("level").and_then(|v| v.as_str()), Some("info"));
        assert_eq!(parsed.get("model").and_then(|v| v.as_str()), Some("live"));
        assert_eq!(parsed.get("generation").and_then(|v| v.as_usize()), Some(3));
        assert!(parsed.get("ts_ms").and_then(|v| v.as_f64()).unwrap_or(-1.0) >= 0.0);
    }

    #[test]
    fn event_fields_escape_cleanly() {
        let mut buf = Vec::new();
        write_event_to(
            &mut buf,
            Level::Debug,
            "request",
            vec![("request_id", Json::Str("a\"b\nc".into()))],
        )
        .unwrap();
        let line = String::from_utf8(buf).unwrap();
        assert_eq!(line.matches('\n').count(), 1, "escaped newline must not split the line");
        let parsed = Json::parse(line.trim()).unwrap();
        assert_eq!(parsed.get("request_id").and_then(|v| v.as_str()), Some("a\"b\nc"));
    }
}
