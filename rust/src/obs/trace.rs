//! The stage taxonomy, per-request stage accumulator and the per-model
//! trace ring buffer.
//!
//! A *stage* is one leg of a request's journey through the serving
//! pipeline (HTTP parse → queue wait → batch formation → the engine's
//! predict phases → serialize) or through the online-update path
//! (drain → absorb → publish). The taxonomy is a fixed enum so a
//! request's whole breakdown fits in one `Copy` array ([`StageSet`]) —
//! recording a stage is an array store, never an allocation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::timer::PhaseProfiler;

/// Number of stages in the taxonomy (the length of a [`StageSet`]).
pub const STAGE_COUNT: usize = 17;

/// One leg of the request pipeline. The discriminant is the index into
/// [`StageSet`] / the per-stage histogram array, so the order is ABI for
/// the metrics layer — append, never reorder.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Stage {
    /// Reading + parsing the HTTP request head and body.
    HttpParse = 0,
    /// Enqueue into the batcher until the batcher thread dequeues it.
    QueueWait = 1,
    /// Dequeued but waiting for the micro-batch to fill or expire.
    BatchForm = 2,
    /// Per-batch scratch pool acquisition/resize inside the engine.
    ScratchAcquire = 3,
    /// Test-side kernel columns (k_S* and per-block k_m*).
    TestSide = 4,
    /// The banded R̄_DU sweep.
    SweepRbarDu = 5,
    /// The Σ̄ diagonal assembly.
    SigmaBar = 6,
    /// Per-block local summaries.
    LocalSummaries = 7,
    /// Global summary reduction.
    GlobalSummary = 8,
    /// The Theorem-2 predictive tail (S-side solves).
    Theorem2 = 9,
    /// The reduced-precision f32 U-side path (when `--f32-u` is active).
    F32U = 10,
    /// Engine time not attributed to a named phase (parallel backends,
    /// legacy paths, profiler gaps).
    EngineOther = 11,
    /// Response JSON construction + write.
    Serialize = 12,
    /// Online update: draining the ingest buffer + planning the blocks.
    ObserveDrain = 13,
    /// Online update: the incremental `absorb` seam recompute.
    ObserveAbsorb = 14,
    /// Online update: building + publishing the new engine generation.
    ObservePublish = 15,
    /// Online update: prequential quality scoring of the drained batch
    /// against the current generation (before `absorb` consumes it).
    ObserveScore = 16,
}

/// Every stage, in index order.
pub const ALL_STAGES: [Stage; STAGE_COUNT] = [
    Stage::HttpParse,
    Stage::QueueWait,
    Stage::BatchForm,
    Stage::ScratchAcquire,
    Stage::TestSide,
    Stage::SweepRbarDu,
    Stage::SigmaBar,
    Stage::LocalSummaries,
    Stage::GlobalSummary,
    Stage::Theorem2,
    Stage::F32U,
    Stage::EngineOther,
    Stage::Serialize,
    Stage::ObserveDrain,
    Stage::ObserveAbsorb,
    Stage::ObservePublish,
    Stage::ObserveScore,
];

impl Stage {
    /// The metric label value (`pgpr_stage_seconds{stage="..."}`).
    pub fn name(self) -> &'static str {
        match self {
            Stage::HttpParse => "http_parse",
            Stage::QueueWait => "queue_wait",
            Stage::BatchForm => "batch_form",
            Stage::ScratchAcquire => "scratch_acquire",
            Stage::TestSide => "test_side",
            Stage::SweepRbarDu => "sweep_rbar_du",
            Stage::SigmaBar => "sigma_bar",
            Stage::LocalSummaries => "local_summaries",
            Stage::GlobalSummary => "global_summary",
            Stage::Theorem2 => "theorem2",
            Stage::F32U => "f32u",
            Stage::EngineOther => "engine_other",
            Stage::Serialize => "serialize",
            Stage::ObserveDrain => "observe_drain",
            Stage::ObserveAbsorb => "observe_absorb",
            Stage::ObservePublish => "observe_publish",
            Stage::ObserveScore => "observe_score",
        }
    }

    /// Map a [`PhaseProfiler`] phase name onto the serving taxonomy.
    /// Named engine predict phases map one-to-one; unnamed `predict/…`
    /// time (parallel backends, legacy recompute) folds into
    /// [`Stage::EngineOther`]; non-predict phases (`fit/…`) are not
    /// serving stages.
    pub fn from_phase(phase: &str) -> Option<Stage> {
        match phase {
            "predict/scratch_acquire" => Some(Stage::ScratchAcquire),
            "predict/test_side" => Some(Stage::TestSide),
            "predict/sweep_rbar_du" => Some(Stage::SweepRbarDu),
            "predict/sigma_bar" => Some(Stage::SigmaBar),
            "predict/local_summaries" => Some(Stage::LocalSummaries),
            "predict/global_summary" => Some(Stage::GlobalSummary),
            "predict/theorem2" => Some(Stage::Theorem2),
            "predict/f32u" => Some(Stage::F32U),
            p if p.starts_with("predict/") => Some(Stage::EngineOther),
            _ => None,
        }
    }
}

/// Per-request stage accumulator: seconds spent in each stage. `Copy`
/// and fixed-size so it travels through the batcher reply channel and
/// into the trace ring without allocating.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageSet {
    secs: [f64; STAGE_COUNT],
}

impl StageSet {
    pub fn new() -> StageSet {
        StageSet::default()
    }

    /// Add `secs` to a stage.
    pub fn add(&mut self, stage: Stage, secs: f64) {
        self.secs[stage as usize] += secs;
    }

    /// Seconds recorded for a stage.
    pub fn get(&self, stage: Stage) -> f64 {
        self.secs[stage as usize]
    }

    /// Total attributed seconds across all stages.
    pub fn sum(&self) -> f64 {
        self.secs.iter().sum()
    }

    /// Element-wise accumulate another set into this one.
    pub fn merge(&mut self, other: &StageSet) {
        for (a, b) in self.secs.iter_mut().zip(&other.secs) {
            *a += b;
        }
    }

    /// Convert an engine-side [`PhaseProfiler`] run into stage times
    /// (predict phases only; see [`Stage::from_phase`]).
    pub fn from_profiler(prof: &PhaseProfiler) -> StageSet {
        let mut set = StageSet::new();
        for (phase, secs) in prof.phases() {
            if let Some(stage) = Stage::from_phase(phase) {
                set.add(stage, secs);
            }
        }
        set
    }

    /// The non-zero stages, in taxonomy order.
    pub fn iter_nonzero(&self) -> impl Iterator<Item = (Stage, f64)> + '_ {
        ALL_STAGES
            .iter()
            .map(move |&s| (s, self.secs[s as usize]))
            .filter(|(_, v)| *v > 0.0)
    }

    /// JSON object of the non-zero stages: `{"queue_wait": 1.2e-4, …}`.
    pub fn to_json(&self) -> Json {
        Json::obj(self.iter_nonzero().map(|(s, v)| (s.name(), Json::Num(v))).collect())
    }
}

/// Process-wide trace-ID counter (IDs are unique per process, never 0).
static NEXT_TRACE_ID: AtomicU64 = AtomicU64::new(1);

/// Allocate the next request trace ID.
pub fn next_trace_id() -> u64 {
    NEXT_TRACE_ID.fetch_add(1, Ordering::Relaxed)
}

/// One completed request trace, as stored in the ring.
#[derive(Clone, Debug)]
pub struct TraceEntry {
    /// Process-assigned trace ID.
    pub trace_id: u64,
    /// Client-supplied `X-Request-Id` ("" when absent).
    pub request_id: String,
    /// Rows in the request.
    pub rows: usize,
    /// HTTP status the request was answered with.
    pub status: u16,
    /// End-to-end latency (submit → reply) in seconds.
    pub total_s: f64,
    /// The per-stage breakdown.
    pub stages: StageSet,
}

impl TraceEntry {
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("trace_id", Json::Num(self.trace_id as f64)),
            ("rows", Json::Num(self.rows as f64)),
            ("status", Json::Num(self.status as f64)),
            ("total_s", Json::Num(self.total_s)),
            ("stages", self.stages.to_json()),
        ];
        if !self.request_id.is_empty() {
            fields.insert(1, ("request_id", Json::Str(self.request_id.clone())));
        }
        Json::obj(fields)
    }
}

/// Lock-cheap ring buffer of the last N completed traces. Writers claim
/// a slot with one relaxed `fetch_add` and hold only that slot's mutex
/// for the store — concurrent pushes to different slots never contend,
/// and readers (`/debug/trace`) never block the whole ring.
pub struct TraceRing {
    slots: Vec<Mutex<Option<TraceEntry>>>,
    head: AtomicU64,
}

impl TraceRing {
    /// A ring holding the last `capacity` traces (0 disables recording).
    pub fn new(capacity: usize) -> TraceRing {
        TraceRing {
            slots: (0..capacity).map(|_| Mutex::new(None)).collect(),
            head: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Record a completed trace (drops it silently when capacity is 0).
    pub fn push(&self, entry: TraceEntry) {
        if self.slots.is_empty() {
            return;
        }
        let i = self.head.fetch_add(1, Ordering::Relaxed) as usize % self.slots.len();
        if let Ok(mut slot) = self.slots[i].lock() {
            *slot = Some(entry);
        }
    }

    /// The last `n` completed traces, newest first.
    pub fn last(&self, n: usize) -> Vec<TraceEntry> {
        let cap = self.slots.len();
        if cap == 0 || n == 0 {
            return Vec::new();
        }
        let head = self.head.load(Ordering::Relaxed) as usize;
        let take = n.min(cap).min(head);
        let mut out = Vec::with_capacity(take);
        for k in 0..take {
            let idx = (head - 1 - k) % cap;
            if let Ok(slot) = self.slots[idx].lock() {
                if let Some(e) = slot.as_ref() {
                    out.push(e.clone());
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_match_taxonomy_order() {
        for (i, s) in ALL_STAGES.iter().enumerate() {
            assert_eq!(*s as usize, i);
        }
        assert_eq!(Stage::QueueWait.name(), "queue_wait");
        assert_eq!(Stage::ObserveScore as usize, STAGE_COUNT - 1);
    }

    #[test]
    fn phase_mapping_covers_predict_taxonomy() {
        assert_eq!(Stage::from_phase("predict/sweep_rbar_du"), Some(Stage::SweepRbarDu));
        assert_eq!(Stage::from_phase("predict/theorem2"), Some(Stage::Theorem2));
        assert_eq!(Stage::from_phase("predict/f32u"), Some(Stage::F32U));
        // Unnamed predict time folds into the engine bucket…
        assert_eq!(Stage::from_phase("predict/parallel"), Some(Stage::EngineOther));
        assert_eq!(Stage::from_phase("predict/context_recompute"), Some(Stage::EngineOther));
        // …and fit phases are not serving stages.
        assert_eq!(Stage::from_phase("fit/core"), None);
    }

    #[test]
    fn stage_set_accumulates_and_sums() {
        let mut s = StageSet::new();
        s.add(Stage::QueueWait, 0.5);
        s.add(Stage::QueueWait, 0.25);
        s.add(Stage::Serialize, 0.125);
        assert_eq!(s.get(Stage::QueueWait), 0.75);
        assert_eq!(s.sum(), 0.875);
        let mut t = StageSet::new();
        t.add(Stage::Serialize, 0.125);
        t.merge(&s);
        assert_eq!(t.get(Stage::Serialize), 0.25);
        let nz: Vec<_> = t.iter_nonzero().map(|(s, _)| s.name()).collect();
        assert_eq!(nz, vec!["queue_wait", "serialize"]);
    }

    #[test]
    fn stage_set_from_profiler_maps_phases() {
        let mut prof = PhaseProfiler::new();
        prof.add("predict/test_side", 0.1);
        prof.add("predict/theorem2", 0.2);
        prof.add("predict/parallel", 0.4);
        prof.add("fit/core", 9.0);
        let s = StageSet::from_profiler(&prof);
        assert_eq!(s.get(Stage::TestSide), 0.1);
        assert_eq!(s.get(Stage::Theorem2), 0.2);
        assert_eq!(s.get(Stage::EngineOther), 0.4);
        assert!((s.sum() - 0.7).abs() < 1e-12, "fit phases must not leak in");
    }

    #[test]
    fn trace_ids_are_unique_and_nonzero() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert!(a > 0 && b > a);
    }

    #[test]
    fn ring_wraps_and_returns_newest_first() {
        let ring = TraceRing::new(4);
        assert!(ring.last(8).is_empty());
        for i in 1..=10u64 {
            ring.push(TraceEntry {
                trace_id: i,
                request_id: String::new(),
                rows: 1,
                status: 200,
                total_s: 0.001,
                stages: StageSet::new(),
            });
        }
        let ids: Vec<u64> = ring.last(8).iter().map(|e| e.trace_id).collect();
        assert_eq!(ids, vec![10, 9, 8, 7], "capacity 4 keeps the last 4, newest first");
        let ids: Vec<u64> = ring.last(2).iter().map(|e| e.trace_id).collect();
        assert_eq!(ids, vec![10, 9]);
    }

    #[test]
    fn zero_capacity_ring_is_inert() {
        let ring = TraceRing::new(0);
        ring.push(TraceEntry {
            trace_id: 1,
            request_id: "abc".into(),
            rows: 1,
            status: 200,
            total_s: 0.0,
            stages: StageSet::new(),
        });
        assert!(ring.last(4).is_empty());
    }

    #[test]
    fn trace_entry_json_includes_request_id_only_when_set() {
        let mut stages = StageSet::new();
        stages.add(Stage::QueueWait, 0.25);
        let e = TraceEntry {
            trace_id: 7,
            request_id: "client-1".into(),
            rows: 2,
            status: 200,
            total_s: 0.5,
            stages,
        };
        let s = e.to_json().to_string();
        assert!(s.contains("\"request_id\":\"client-1\""), "{s}");
        assert!(s.contains("\"queue_wait\":0.25"), "{s}");
        let e2 = TraceEntry { request_id: String::new(), ..e };
        assert!(!e2.to_json().to_string().contains("request_id"));
    }
}
