//! Model-quality & drift observability: prequential scoring accumulators.
//!
//! The online-update path scores every arriving observation (test-then-
//! train: the row is scored against the *current* generation before
//! `absorb` consumes it) and records squared error, per-row NLPD and the
//! standardized residual z = (y − μ)/σ into a per-model sliding window —
//! a ring of fixed-width row buckets, lock-cheap like `TraceRing` — so
//! the surfaces report rolling RMSE/MNLP/coverage-at-90% over the last
//! W rows rather than cumulative-since-boot averages. Each scored row is
//! also attributed to the Markov block the update plan routes it into,
//! giving a per-block error profile that shows *where* in input space
//! the model is degrading.
//!
//! Drift is the windowed MNLP measured against the fit-time held-out
//! baseline persisted in the artifact manifest:
//!
//! ```text
//! drift_score = windowed MNLP − baseline MNLP      (nats per row)
//! ```
//!
//! A `drift_detected` event fires exactly once per upward crossing of
//! `--drift-threshold` (the detector re-arms when the score falls back
//! below the threshold).
//!
//! This module is pure accumulators + math: prediction itself stays in
//! the registry (which owns the engine and the pooled `PredictScratch`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::error::{PgprError, Result};
use crate::util::json::Json;

/// |z| bound containing 90% of a standard normal (Φ⁻¹(0.95)).
pub const Z90: f64 = 1.6448536269514722;

/// Number of buckets in a quality window ring. The requested window is
/// rounded up to a multiple of this so each bucket covers the same
/// number of rows.
pub const QUALITY_BUCKETS: usize = 32;

/// How many rows of each drained batch the prequential scorer evaluates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoreMode {
    /// No scoring; every quality surface stays empty.
    Off,
    /// Score up to K evenly-spaced rows per drained batch.
    Sample(usize),
    /// Score every row.
    All,
}

impl Default for ScoreMode {
    /// The serving default: 16 evenly-spaced rows per drained batch.
    fn default() -> ScoreMode {
        ScoreMode::Sample(16)
    }
}

impl ScoreMode {
    /// Parse a CLI/JSON selector: `off`, `all` or `sample:<k>` (k ≥ 1).
    pub fn parse(s: &str) -> Result<ScoreMode> {
        let s = s.trim().to_ascii_lowercase();
        if s == "off" {
            return Ok(ScoreMode::Off);
        }
        if s == "all" {
            return Ok(ScoreMode::All);
        }
        if let Some(k) = s.strip_prefix("sample:") {
            let k: usize = k
                .parse()
                .map_err(|_| PgprError::Config(format!("invalid score sample count: {k:?}")))?;
            if k == 0 {
                return Err(PgprError::Config("score sample count must be >= 1".into()));
            }
            return Ok(ScoreMode::Sample(k));
        }
        Err(PgprError::Config(format!(
            "unknown score mode: {s:?} (expected off|sample:<k>|all)"
        )))
    }

    /// The selector string `parse` accepts (round-trips exactly).
    pub fn selector(&self) -> String {
        match self {
            ScoreMode::Off => "off".into(),
            ScoreMode::Sample(k) => format!("sample:{k}"),
            ScoreMode::All => "all".into(),
        }
    }

    /// The batch row indices this mode scores, strictly increasing.
    /// `Sample(k)` picks k evenly-spaced rows (all rows when the batch is
    /// smaller than k) so a systematic stream is sampled across its whole
    /// span, not just a prefix.
    pub fn indices(&self, rows: usize) -> Vec<usize> {
        match *self {
            ScoreMode::Off => Vec::new(),
            ScoreMode::All => (0..rows).collect(),
            ScoreMode::Sample(k) => {
                if rows <= k {
                    (0..rows).collect()
                } else {
                    (0..k).map(|j| j * rows / k).collect()
                }
            }
        }
    }
}

/// Fit-time held-out accuracy, persisted in the artifact manifest so a
/// serve boot has a comparison point for the drift score.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QualityBaseline {
    /// Held-out RMSE at fit time.
    pub rmse: f64,
    /// Held-out mean negative log predictive density at fit time.
    pub mnlp: f64,
    /// Held-out rows the baseline was computed on.
    pub rows: usize,
}

impl QualityBaseline {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rmse", Json::Num(self.rmse)),
            ("mnlp", Json::Num(self.mnlp)),
            ("rows", Json::Num(self.rows as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<QualityBaseline> {
        let num = |field: &'static str| -> Result<f64> {
            j.req(field)?.as_f64().ok_or_else(|| {
                PgprError::Artifact(format!("quality_baseline.{field}: not a number"))
            })
        };
        Ok(QualityBaseline {
            rmse: num("rmse")?,
            mnlp: num("mnlp")?,
            rows: num("rows")? as usize,
        })
    }
}

/// Per-row NLPD under a Gaussian marginal N(μ, σ²). Term-for-term
/// identical to `crate::metrics::mnlp` (including the variance clamp) so
/// the windowed mean over a stationary stream matches the offline metric
/// bit-for-bit.
pub fn row_nlpd(mean: f64, var: f64, y: f64) -> f64 {
    let ln2pi = (2.0 * std::f64::consts::PI).ln();
    let v = var.max(1e-12);
    0.5 * (ln2pi + v.ln() + (y - mean) * (y - mean) / v)
}

/// One prequentially scored observation, already attributed to the
/// Markov block the update plan routes it into.
#[derive(Clone, Copy, Debug)]
pub struct ScoredRow {
    /// Markov block index the row is absorbed into.
    pub block: usize,
    /// Squared error (y − μ)².
    pub sq_err: f64,
    /// Per-row negative log predictive density.
    pub nlpd: f64,
    /// Standardized residual z = (y − μ)/σ.
    pub z: f64,
}

impl ScoredRow {
    /// Score one observation against a predictive marginal N(μ, σ²).
    pub fn score(block: usize, mean: f64, var: f64, y: f64) -> ScoredRow {
        let d = y - mean;
        ScoredRow {
            block,
            sq_err: d * d,
            nlpd: row_nlpd(mean, var, y),
            z: d / var.max(1e-12).sqrt(),
        }
    }
}

/// Per-block partial sums inside one bucket.
#[derive(Clone, Copy, Debug, Default)]
struct BlockAcc {
    count: u64,
    sum_sq: f64,
    sum_nlpd: f64,
}

/// One fixed-width window bucket: aggregate sums over up to
/// `bucket_rows` consecutively scored rows.
#[derive(Clone, Debug, Default)]
struct Bucket {
    /// Monotone bucket number, 1-based (0 = slot never used).
    seq: u64,
    count: u64,
    sum_sq: f64,
    sum_nlpd: f64,
    /// Rows with |z| ≤ Z90 (inside the central 90% interval).
    covered: u64,
    blocks: BTreeMap<usize, BlockAcc>,
}

impl Bucket {
    fn add(&mut self, r: &ScoredRow) {
        self.count += 1;
        self.sum_sq += r.sq_err;
        self.sum_nlpd += r.nlpd;
        if r.z.abs() <= Z90 {
            self.covered += 1;
        }
        let b = self.blocks.entry(r.block).or_default();
        b.count += 1;
        b.sum_sq += r.sq_err;
        b.sum_nlpd += r.nlpd;
    }
}

/// Aggregate statistics over the live window.
#[derive(Clone, Copy, Debug, Default)]
pub struct WindowStats {
    /// Scored rows currently inside the window.
    pub rows: u64,
    /// Rolling root mean square error.
    pub rmse: f64,
    /// Rolling mean negative log predictive density.
    pub mnlp: f64,
    /// Fraction of rows with |z| ≤ Z90.
    pub coverage90: f64,
}

/// Windowed per-block error profile entry.
#[derive(Clone, Copy, Debug)]
pub struct BlockStats {
    /// Markov block index.
    pub block: usize,
    /// Scored rows attributed to the block inside the window.
    pub rows: u64,
    /// Rolling RMSE over those rows.
    pub rmse: f64,
    /// Rolling MNLP over those rows.
    pub mnlp: f64,
}

/// One bucket of the windowed series (newest first in `series`).
#[derive(Clone, Copy, Debug)]
pub struct BucketStats {
    /// Monotone bucket number (1-based).
    pub seq: u64,
    pub rows: u64,
    pub rmse: f64,
    pub mnlp: f64,
    pub coverage90: f64,
}

/// Sliding window of the last `bucket_rows × QUALITY_BUCKETS` scored
/// rows. Writers are serialized by the registry's per-model ingest lock,
/// so pushes lock only the active bucket; readers lock one bucket at a
/// time and never block the whole ring. Capacity 0 disables recording.
pub struct QualityWindow {
    /// Rows per bucket (0 = inert window).
    bucket_rows: usize,
    slots: Vec<Mutex<Bucket>>,
    /// Index of the bucket currently being filled (monotone; slot is
    /// `head % slots.len()`).
    head: AtomicU64,
    /// Total rows ever scored (not windowed).
    scored: AtomicU64,
}

impl QualityWindow {
    /// A window covering at least `window_rows` rows (rounded up to a
    /// whole number of buckets; 0 disables recording).
    pub fn new(window_rows: usize) -> QualityWindow {
        let bucket_rows = if window_rows == 0 {
            0
        } else {
            window_rows.div_ceil(QUALITY_BUCKETS).max(1)
        };
        let slots = if bucket_rows == 0 { 0 } else { QUALITY_BUCKETS };
        QualityWindow {
            bucket_rows,
            slots: (0..slots).map(|_| Mutex::new(Bucket::default())).collect(),
            head: AtomicU64::new(0),
            scored: AtomicU64::new(0),
        }
    }

    /// Rows the window holds when full.
    pub fn capacity_rows(&self) -> usize {
        self.bucket_rows * self.slots.len()
    }

    /// Total rows ever scored through this window.
    pub fn scored_rows(&self) -> u64 {
        self.scored.load(Ordering::Relaxed)
    }

    /// Record one scored row (drops it silently when capacity is 0).
    pub fn push(&self, r: &ScoredRow) {
        if self.slots.is_empty() {
            return;
        }
        self.scored.fetch_add(1, Ordering::Relaxed);
        let cap = self.slots.len() as u64;
        let mut head = self.head.load(Ordering::Relaxed);
        {
            let mut b = self.slots[(head % cap) as usize].lock().unwrap();
            if b.seq == 0 {
                b.seq = head + 1;
            }
            if b.count < self.bucket_rows as u64 {
                b.add(r);
                return;
            }
        }
        // Active bucket is full: advance the head and start a fresh one.
        head += 1;
        self.head.store(head, Ordering::Relaxed);
        let mut b = self.slots[(head % cap) as usize].lock().unwrap();
        *b = Bucket { seq: head + 1, ..Bucket::default() };
        b.add(r);
    }

    /// Aggregate RMSE/MNLP/coverage over every live bucket.
    pub fn stats(&self) -> WindowStats {
        let mut rows = 0u64;
        let mut covered = 0u64;
        let mut sum_sq = 0.0;
        let mut sum_nlpd = 0.0;
        self.for_each_oldest_first(|b| {
            rows += b.count;
            covered += b.covered;
            sum_sq += b.sum_sq;
            sum_nlpd += b.sum_nlpd;
        });
        if rows == 0 {
            return WindowStats::default();
        }
        let n = rows as f64;
        WindowStats {
            rows,
            rmse: (sum_sq / n).sqrt(),
            mnlp: sum_nlpd / n,
            coverage90: covered as f64 / n,
        }
    }

    /// The windowed per-block error profile, worst (highest RMSE) first.
    pub fn worst_blocks(&self, k: usize) -> Vec<BlockStats> {
        let mut acc: BTreeMap<usize, BlockAcc> = BTreeMap::new();
        self.for_each_oldest_first(|b| {
            for (&blk, a) in &b.blocks {
                let e = acc.entry(blk).or_default();
                e.count += a.count;
                e.sum_sq += a.sum_sq;
                e.sum_nlpd += a.sum_nlpd;
            }
        });
        let mut out: Vec<BlockStats> = acc
            .into_iter()
            .filter(|(_, a)| a.count > 0)
            .map(|(blk, a)| {
                let n = a.count as f64;
                BlockStats {
                    block: blk,
                    rows: a.count,
                    rmse: (a.sum_sq / n).sqrt(),
                    mnlp: a.sum_nlpd / n,
                }
            })
            .collect();
        out.sort_by(|a, b| b.rmse.partial_cmp(&a.rmse).unwrap_or(std::cmp::Ordering::Equal));
        out.truncate(k);
        out
    }

    /// The last `n` buckets with any rows, newest first.
    pub fn series(&self, n: usize) -> Vec<BucketStats> {
        let mut live: Vec<BucketStats> = Vec::new();
        self.for_each_oldest_first(|b| {
            if b.count > 0 {
                let c = b.count as f64;
                live.push(BucketStats {
                    seq: b.seq,
                    rows: b.count,
                    rmse: (b.sum_sq / c).sqrt(),
                    mnlp: b.sum_nlpd / c,
                    coverage90: b.covered as f64 / c,
                });
            }
        });
        live.reverse();
        live.truncate(n);
        live
    }

    /// Visit every live bucket, oldest first (stable aggregation order).
    fn for_each_oldest_first(&self, mut f: impl FnMut(&Bucket)) {
        let cap = self.slots.len();
        if cap == 0 {
            return;
        }
        let head = self.head.load(Ordering::Relaxed) as usize;
        // Oldest live bucket is head+1 when the ring has wrapped.
        for k in 0..cap {
            let idx = (head + 1 + k) % cap;
            let b = self.slots[idx].lock().unwrap();
            if b.seq > 0 {
                f(&b);
            }
        }
    }
}

/// A drift-threshold upward crossing reported by [`ModelQuality::record`].
#[derive(Clone, Copy, Debug)]
pub struct DriftCrossing {
    /// The drift score at the crossing (windowed MNLP − baseline MNLP).
    pub score: f64,
    /// Windowed MNLP at the crossing.
    pub window_mnlp: f64,
    /// The fit-time baseline MNLP.
    pub baseline_mnlp: f64,
}

/// Per-model quality state: the sliding window, the fit-time baseline
/// and the drift detector. Shared across generations via `Arc` (a new
/// generation continues the same window — the stream is one stream).
pub struct ModelQuality {
    mode: ScoreMode,
    window: QualityWindow,
    baseline: Option<QualityBaseline>,
    drift_threshold: f64,
    /// True while the drift score sits above the threshold; the
    /// `drift_detected` event fires only on the false→true edge.
    drift_active: AtomicBool,
    /// Upward crossings observed so far.
    drift_events: AtomicU64,
}

impl ModelQuality {
    pub fn new(
        mode: ScoreMode,
        window_rows: usize,
        drift_threshold: f64,
        baseline: Option<QualityBaseline>,
    ) -> ModelQuality {
        let window_rows = if mode == ScoreMode::Off { 0 } else { window_rows };
        ModelQuality {
            mode,
            window: QualityWindow::new(window_rows),
            baseline,
            drift_threshold,
            drift_active: AtomicBool::new(false),
            drift_events: AtomicU64::new(0),
        }
    }

    /// Whether the scorer runs at all (mode ≠ off and a live window).
    pub fn enabled(&self) -> bool {
        self.mode != ScoreMode::Off && self.window.capacity_rows() > 0
    }

    pub fn mode(&self) -> ScoreMode {
        self.mode
    }

    pub fn baseline(&self) -> Option<QualityBaseline> {
        self.baseline
    }

    pub fn scored_rows(&self) -> u64 {
        self.window.scored_rows()
    }

    pub fn drift_events(&self) -> u64 {
        self.drift_events.load(Ordering::Relaxed)
    }

    pub fn stats(&self) -> WindowStats {
        self.window.stats()
    }

    pub fn worst_blocks(&self, k: usize) -> Vec<BlockStats> {
        self.window.worst_blocks(k)
    }

    pub fn series(&self, n: usize) -> Vec<BucketStats> {
        self.window.series(n)
    }

    /// Windowed MNLP minus the fit-time baseline MNLP; `None` without a
    /// baseline or before any row has been scored.
    pub fn drift_score(&self) -> Option<f64> {
        let b = self.baseline?;
        let s = self.window.stats();
        if s.rows == 0 {
            return None;
        }
        Some(s.mnlp - b.mnlp)
    }

    /// Record a batch of scored rows and run the drift detector. Returns
    /// `Some` exactly when the drift score crosses the threshold upward
    /// (the caller emits the `drift_detected` event); the detector
    /// re-arms when the score falls back below the threshold.
    pub fn record(&self, scored: &[ScoredRow]) -> Option<DriftCrossing> {
        if !self.enabled() || scored.is_empty() {
            return None;
        }
        for r in scored {
            self.window.push(r);
        }
        let b = self.baseline?;
        let s = self.window.stats();
        if s.rows == 0 {
            return None;
        }
        let score = s.mnlp - b.mnlp;
        if score > self.drift_threshold {
            if !self.drift_active.swap(true, Ordering::Relaxed) {
                self.drift_events.fetch_add(1, Ordering::Relaxed);
                return Some(DriftCrossing {
                    score,
                    window_mnlp: s.mnlp,
                    baseline_mnlp: b.mnlp,
                });
            }
        } else {
            self.drift_active.store(false, Ordering::Relaxed);
        }
        None
    }

    /// The `quality` object for `?format=json` / `/models/<name>`.
    pub fn to_json(&self) -> Json {
        let s = self.window.stats();
        let mut fields: Vec<(&str, Json)> = vec![
            ("mode", Json::Str(self.mode.selector())),
            ("scored_rows", Json::Num(self.scored_rows() as f64)),
            ("window_capacity", Json::Num(self.window.capacity_rows() as f64)),
            ("window_rows", Json::Num(s.rows as f64)),
            ("drift_threshold", Json::Num(self.drift_threshold)),
            ("drift_events", Json::Num(self.drift_events() as f64)),
        ];
        if s.rows > 0 {
            fields.push(("rmse", Json::Num(s.rmse)));
            fields.push(("mnlp", Json::Num(s.mnlp)));
            fields.push(("coverage90", Json::Num(s.coverage90)));
        }
        if let Some(b) = self.baseline {
            fields.push(("baseline", b.to_json()));
        }
        if let Some(d) = self.drift_score() {
            fields.push(("drift_score", Json::Num(d)));
        }
        Json::obj(fields)
    }

    /// The `GET /debug/quality` payload: summary + windowed series +
    /// top-k worst blocks.
    pub fn debug_json(&self, n: usize, k: usize) -> Json {
        let series: Vec<Json> = self
            .series(n)
            .into_iter()
            .map(|b| {
                Json::obj(vec![
                    ("bucket", Json::Num(b.seq as f64)),
                    ("rows", Json::Num(b.rows as f64)),
                    ("rmse", Json::Num(b.rmse)),
                    ("mnlp", Json::Num(b.mnlp)),
                    ("coverage90", Json::Num(b.coverage90)),
                ])
            })
            .collect();
        let blocks: Vec<Json> = self
            .worst_blocks(k)
            .into_iter()
            .map(|b| {
                Json::obj(vec![
                    ("block", Json::Num(b.block as f64)),
                    ("rows", Json::Num(b.rows as f64)),
                    ("rmse", Json::Num(b.rmse)),
                    ("mnlp", Json::Num(b.mnlp)),
                ])
            })
            .collect();
        let mut map = match self.to_json() {
            Json::Obj(m) => m,
            _ => BTreeMap::new(),
        };
        map.insert("enabled".into(), Json::Bool(self.enabled()));
        map.insert("series".into(), Json::Arr(series));
        map.insert("worst_blocks".into(), Json::Arr(blocks));
        Json::Obj(map)
    }
}

/// Map a drained-batch row index onto the Markov block the update plan
/// absorbs it into: the first `extend_tail` rows extend block
/// `m_before − 1`, the rest fill `new_blocks` in order starting at
/// block `m_before`.
pub fn block_of_row(i: usize, extend_tail: usize, new_blocks: &[usize], m_before: usize) -> usize {
    if i < extend_tail {
        return m_before.saturating_sub(1);
    }
    let mut off = i - extend_tail;
    let mut blk = m_before;
    for &sz in new_blocks {
        if off < sz {
            return blk;
        }
        off -= sz;
        blk += 1;
    }
    // Past the plan's declared rows (defensive): attribute to the last
    // planned block.
    blk.saturating_sub(1).max(m_before.saturating_sub(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_mode_parses_and_round_trips() {
        assert_eq!(ScoreMode::parse("off").unwrap(), ScoreMode::Off);
        assert_eq!(ScoreMode::parse("ALL").unwrap(), ScoreMode::All);
        assert_eq!(ScoreMode::parse(" sample:4 ").unwrap(), ScoreMode::Sample(4));
        assert!(ScoreMode::parse("sample:0").is_err());
        assert!(ScoreMode::parse("sample:x").is_err());
        assert!(ScoreMode::parse("half").is_err());
        for m in [ScoreMode::Off, ScoreMode::All, ScoreMode::Sample(16)] {
            assert_eq!(ScoreMode::parse(&m.selector()).unwrap(), m);
        }
        assert_eq!(ScoreMode::default(), ScoreMode::Sample(16));
    }

    #[test]
    fn sample_indices_are_strictly_increasing_and_span_the_batch() {
        assert!(ScoreMode::Off.indices(10).is_empty());
        assert_eq!(ScoreMode::All.indices(3), vec![0, 1, 2]);
        assert_eq!(ScoreMode::Sample(8).indices(5), vec![0, 1, 2, 3, 4]);
        let idx = ScoreMode::Sample(4).indices(100);
        assert_eq!(idx, vec![0, 25, 50, 75]);
        for w in idx.windows(2) {
            assert!(w[0] < w[1]);
        }
        let idx = ScoreMode::Sample(16).indices(17);
        assert_eq!(idx.len(), 16);
        for w in idx.windows(2) {
            assert!(w[0] < w[1], "{idx:?}");
        }
    }

    #[test]
    fn row_nlpd_matches_offline_mnlp() {
        let mean = [0.5, -1.0, 2.0];
        let var = [0.25, 1.5, 0.0];
        let truth = [0.75, -0.5, 2.0];
        let offline = crate::metrics::mnlp(&mean, &var, &truth);
        let total: f64 =
            (0..3).map(|i| row_nlpd(mean[i], var[i], truth[i])).sum();
        assert_eq!((total / 3.0).to_bits(), offline.to_bits());
    }

    #[test]
    fn window_forgets_old_buckets() {
        // Window of exactly QUALITY_BUCKETS rows: one row per bucket.
        let w = QualityWindow::new(QUALITY_BUCKETS);
        assert_eq!(w.capacity_rows(), QUALITY_BUCKETS);
        for _ in 0..QUALITY_BUCKETS {
            w.push(&ScoredRow { block: 0, sq_err: 4.0, nlpd: 3.0, z: 5.0 });
        }
        let s = w.stats();
        assert_eq!(s.rows as usize, QUALITY_BUCKETS);
        assert!((s.rmse - 2.0).abs() < 1e-12);
        assert_eq!(s.coverage90, 0.0);
        // A full window of good rows pushes every bad bucket out.
        for _ in 0..QUALITY_BUCKETS {
            w.push(&ScoredRow { block: 1, sq_err: 0.01, nlpd: 0.5, z: 0.1 });
        }
        let s = w.stats();
        assert_eq!(s.rows as usize, QUALITY_BUCKETS);
        assert!((s.rmse - 0.1).abs() < 1e-12, "rmse={}", s.rmse);
        assert_eq!(s.coverage90, 1.0);
        assert_eq!(w.scored_rows() as usize, 2 * QUALITY_BUCKETS);
        // The per-block profile is windowed too: block 0 has been
        // forgotten along with its buckets.
        let blocks = w.worst_blocks(8);
        assert_eq!(blocks.len(), 1);
        assert_eq!(blocks[0].block, 1);
    }

    #[test]
    fn worst_blocks_rank_by_windowed_rmse() {
        let w = QualityWindow::new(64);
        for _ in 0..8 {
            w.push(&ScoredRow { block: 2, sq_err: 9.0, nlpd: 4.0, z: 3.0 });
            w.push(&ScoredRow { block: 0, sq_err: 0.04, nlpd: 0.1, z: 0.2 });
            w.push(&ScoredRow { block: 1, sq_err: 1.0, nlpd: 1.0, z: 1.0 });
        }
        let blocks = w.worst_blocks(2);
        assert_eq!(blocks.len(), 2);
        assert_eq!(blocks[0].block, 2);
        assert!((blocks[0].rmse - 3.0).abs() < 1e-12);
        assert_eq!(blocks[1].block, 1);
        let all = w.worst_blocks(8);
        assert_eq!(all.len(), 3);
        assert_eq!(all[2].block, 0);
    }

    #[test]
    fn series_is_newest_first() {
        let w = QualityWindow::new(QUALITY_BUCKETS * 2); // 2 rows per bucket
        for i in 0..6 {
            let sq = (i / 2 + 1) as f64;
            w.push(&ScoredRow { block: 0, sq_err: sq * sq, nlpd: sq, z: 0.0 });
        }
        let s = w.series(2);
        assert_eq!(s.len(), 2);
        assert!(s[0].seq > s[1].seq);
        assert!((s[0].rmse - 3.0).abs() < 1e-12);
        assert!((s[1].rmse - 2.0).abs() < 1e-12);
    }

    #[test]
    fn zero_window_is_inert() {
        let w = QualityWindow::new(0);
        w.push(&ScoredRow { block: 0, sq_err: 1.0, nlpd: 1.0, z: 1.0 });
        assert_eq!(w.stats().rows, 0);
        assert!(w.series(4).is_empty());
        assert!(w.worst_blocks(4).is_empty());
        let q = ModelQuality::new(ScoreMode::Off, 1024, 1.0, None);
        assert!(!q.enabled());
        assert!(q
            .record(&[ScoredRow { block: 0, sq_err: 1.0, nlpd: 1.0, z: 1.0 }])
            .is_none());
        assert_eq!(q.scored_rows(), 0);
    }

    #[test]
    fn drift_fires_once_per_crossing_and_rearms() {
        let baseline = QualityBaseline { rmse: 0.1, mnlp: 0.0, rows: 64 };
        let q = ModelQuality::new(ScoreMode::All, QUALITY_BUCKETS, 1.0, Some(baseline));
        let bad = ScoredRow { block: 0, sq_err: 4.0, nlpd: 5.0, z: 4.0 };
        let good = ScoredRow { block: 0, sq_err: 0.01, nlpd: 0.1, z: 0.1 };
        // First bad batch crosses: exactly one event.
        let c = q.record(&[bad; 4]).expect("first crossing fires");
        assert!(c.score > 1.0);
        assert!((c.baseline_mnlp - 0.0).abs() < 1e-12);
        // Still above threshold: no re-fire.
        assert!(q.record(&[bad; 4]).is_none());
        assert_eq!(q.drift_events(), 1);
        // A full window of good rows brings the score back down…
        for _ in 0..QUALITY_BUCKETS {
            assert!(q.record(&[good]).is_none());
        }
        assert!(q.drift_score().unwrap() < 1.0);
        // …and the detector re-arms: the next crossing fires again.
        let mut fired = 0;
        for _ in 0..QUALITY_BUCKETS {
            if q.record(&[bad]).is_some() {
                fired += 1;
            }
        }
        assert_eq!(fired, 1);
        assert_eq!(q.drift_events(), 2);
    }

    #[test]
    fn no_baseline_means_no_drift_score() {
        let q = ModelQuality::new(ScoreMode::All, 64, 1.0, None);
        q.record(&[ScoredRow { block: 0, sq_err: 100.0, nlpd: 50.0, z: 10.0 }]);
        assert!(q.drift_score().is_none());
        assert_eq!(q.drift_events(), 0);
        assert_eq!(q.stats().rows, 1);
    }

    #[test]
    fn block_of_row_walks_the_plan() {
        // Tail extension first, then two fresh blocks of 3 and 2 rows.
        let new_blocks = [3usize, 2];
        assert_eq!(block_of_row(0, 2, &new_blocks, 4), 3);
        assert_eq!(block_of_row(1, 2, &new_blocks, 4), 3);
        assert_eq!(block_of_row(2, 2, &new_blocks, 4), 4);
        assert_eq!(block_of_row(4, 2, &new_blocks, 4), 4);
        assert_eq!(block_of_row(5, 2, &new_blocks, 4), 5);
        assert_eq!(block_of_row(6, 2, &new_blocks, 4), 5);
        // No tail room: everything goes to fresh blocks.
        assert_eq!(block_of_row(0, 0, &[4], 4), 4);
        assert_eq!(block_of_row(3, 0, &[4], 4), 4);
        // Pure tail extension.
        assert_eq!(block_of_row(0, 5, &[], 1), 0);
        assert_eq!(block_of_row(4, 5, &[], 1), 0);
    }

    #[test]
    fn quality_json_surfaces_match_state() {
        let baseline = QualityBaseline { rmse: 0.2, mnlp: 0.5, rows: 32 };
        let q = ModelQuality::new(ScoreMode::Sample(8), 64, 0.75, Some(baseline));
        q.record(&[ScoredRow { block: 1, sq_err: 1.0, nlpd: 1.5, z: 1.0 }]);
        let s = q.to_json().to_string();
        assert!(s.contains("\"mode\":\"sample:8\""), "{s}");
        assert!(s.contains("\"scored_rows\":1"), "{s}");
        assert!(s.contains("\"rmse\":1"), "{s}");
        assert!(s.contains("\"baseline\":{"), "{s}");
        assert!(s.contains("\"drift_score\":1"), "{s}");
        let d = q.debug_json(4, 4).to_string();
        assert!(d.contains("\"enabled\":true"), "{d}");
        assert!(d.contains("\"series\":[{"), "{d}");
        assert!(d.contains("\"worst_blocks\":[{"), "{d}");
        assert!(d.contains("\"block\":1"), "{d}");
        // Round-trip of the persisted baseline.
        let back = QualityBaseline::from_json(&baseline.to_json()).unwrap();
        assert_eq!(back, baseline);
    }
}
