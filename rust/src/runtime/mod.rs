//! PJRT execution runtime: loads the HLO-text artifacts produced by the
//! python AOT pass (`python/compile/aot.py`) and executes them on the
//! request path through the `xla` crate's PJRT CPU client.
//!
//! Interchange is HLO **text**, not serialized protos — jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod pjrt;
pub mod artifacts;
