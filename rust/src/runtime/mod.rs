//! PJRT execution runtime: loads the HLO-text artifacts produced by the
//! python AOT pass (`python/compile/aot.py`) and executes them on the
//! request path through the `xla` crate's PJRT CPU client.
//!
//! Interchange is HLO **text**, not serialized protos — jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The whole path is gated behind the `pjrt` cargo feature (which needs
//! the `xla` bindings crate vendored into the build). Without the feature
//! the [`artifacts`] module is a stub whose loader always reports "not
//! built", so `CovBackend::auto()` and `pgpr bench-info` compile and fall
//! back to the native covariance path on machines without artifacts or a
//! PJRT plugin.

#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(feature = "pjrt")]
pub mod artifacts;

#[cfg(not(feature = "pjrt"))]
mod artifacts_stub;
#[cfg(not(feature = "pjrt"))]
pub use artifacts_stub as artifacts;
