//! Thin safe wrapper around the `xla` crate's PJRT CPU client.

use std::path::Path;

use crate::util::error::{PgprError, Result};

/// A PJRT client plus helpers to load/compile HLO-text modules.
pub struct PjrtEngine {
    client: xla::PjRtClient,
}

/// A compiled executable with f32 tensor I/O.
pub struct PjrtExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl PjrtEngine {
    /// Create the CPU client.
    pub fn cpu() -> Result<PjrtEngine> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| PgprError::Pjrt(format!("client: {e}")))?;
        Ok(PjrtEngine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text file and compile it.
    pub fn compile_hlo_text(&self, path: &Path, name: &str) -> Result<PjrtExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| PgprError::Artifact(format!("non-utf8 path {path:?}")))?,
        )
        .map_err(|e| PgprError::Artifact(format!("parse {path:?}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| PgprError::Pjrt(format!("compile {name}: {e}")))?;
        Ok(PjrtExecutable { exe, name: name.to_string() })
    }
}

impl PjrtExecutable {
    /// Execute with f32 inputs of the given shapes; returns the flattened
    /// f32 output of the first tuple element (our AOT graphs return
    /// 1-tuples, per the gen_hlo.py convention).
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| PgprError::Pjrt(format!("reshape input for {}: {e}", self.name)))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| PgprError::Pjrt(format!("execute {}: {e}", self.name)))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| PgprError::Pjrt(format!("fetch {}: {e}", self.name)))?;
        let out = lit
            .to_tuple1()
            .map_err(|e| PgprError::Pjrt(format!("untuple {}: {e}", self.name)))?;
        out.to_vec::<f32>()
            .map_err(|e| PgprError::Pjrt(format!("to_vec {}: {e}", self.name)))
    }
}
