//! Stub artifact library, compiled when the `pjrt` feature is **off**.
//!
//! Mirrors the API surface of the real `runtime::artifacts` (so
//! `kernels::pjrt_cov::CovBackend`, `pgpr bench-info` and the integration
//! tests compile unchanged) but can never be constructed: `load` always
//! fails with an `Artifact` error and `try_default` returns `None`, which
//! every caller already treats as "native covariance path only".

use std::path::{Path, PathBuf};

use crate::linalg::matrix::Mat;
use crate::util::error::{PgprError, Result};

/// One artifact entry from the manifest (mirror of the `pjrt` build).
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub n1: usize,
    pub n2: usize,
    pub d: usize,
}

/// Placeholder library — unconstructible without the `pjrt` feature.
pub struct ArtifactLibrary {
    #[allow(dead_code)]
    unconstructible: (),
}

impl ArtifactLibrary {
    /// Default location relative to the repo root.
    pub fn default_dir() -> PathBuf {
        std::env::var("PGPR_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Always fails: the PJRT path was compiled out.
    pub fn load(_dir: &Path) -> Result<ArtifactLibrary> {
        Err(PgprError::Artifact(
            "pgpr was built without the `pjrt` feature; rebuild with `--features pjrt` \
             (requires the vendored `xla` crate) to execute HLO artifacts"
                .into(),
        ))
    }

    /// Always `None`: callers fall back to the native covariance path.
    pub fn try_default() -> Option<ArtifactLibrary> {
        None
    }

    pub fn entries(&self) -> &[ArtifactEntry] {
        &[]
    }

    pub fn cov_cross_scaled(&self, _s1: &Mat, _s2: &Mat, _sigma_s2: f64) -> Result<Mat> {
        Err(PgprError::Artifact("pjrt feature disabled".into()))
    }

    pub fn summary_gram(&self, _v: &Mat, _acc: &Mat) -> Result<Mat> {
        Err(PgprError::Artifact("pjrt feature disabled".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_loader_reports_feature_disabled() {
        assert!(ArtifactLibrary::try_default().is_none());
        match ArtifactLibrary::load(Path::new("artifacts")) {
            Err(PgprError::Artifact(msg)) => assert!(msg.contains("pjrt")),
            Err(e) => panic!("unexpected error kind: {e}"),
            Ok(_) => panic!("stub load must fail"),
        }
    }

    #[test]
    fn default_dir_honors_env() {
        // Just exercise the path logic; don't mutate global env here.
        let d = ArtifactLibrary::default_dir();
        assert!(!d.as_os_str().is_empty());
    }
}
