//! Manifest-driven artifact library with shape buckets.
//!
//! `python/compile/aot.py` lowers the Layer-1/2 graphs once per shape
//! bucket and writes `artifacts/manifest.json`:
//!
//! ```json
//! { "artifacts": [
//!     {"name": "cov_cross", "file": "cov_cross_128x128.hlo.txt",
//!      "n1": 128, "n2": 128, "d": 24 }, ... ] }
//! ```
//!
//! PJRT executables have static shapes, so [`ArtifactLibrary`] pads
//! inputs up to the smallest bucket that fits (zero padding is exact for
//! the scaled-distance kernel: padded feature columns contribute 0 to the
//! distance, padded rows are sliced away on unpadding) and caches one
//! compiled executable per bucket, compiled lazily on first use.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::linalg::matrix::Mat;
use crate::runtime::pjrt::{PjrtEngine, PjrtExecutable};
use crate::util::error::{PgprError, Result};
use crate::util::json::Json;

/// One artifact entry from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub n1: usize,
    pub n2: usize,
    pub d: usize,
}

/// The artifact library: manifest + lazily compiled executables.
pub struct ArtifactLibrary {
    dir: PathBuf,
    engine: PjrtEngine,
    entries: Vec<ArtifactEntry>,
    cache: Mutex<HashMap<String, Arc<PjrtExecutable>>>,
}

// SAFETY: the compiled-executable cache is Mutex-guarded, and the PJRT C
// API specifies thread-safe clients/`Execute`. The `xla` *Rust wrapper*,
// however, does not declare Send/Sync, and some versions share handles
// via non-atomic `Rc` internally — this marker asserts the vendored
// build uses thread-safe handle types, which MUST be checked when
// vendoring the crate. Defense in depth: `LmaFitCore` forces its
// per-block worker count to 1 whenever the PJRT covariance backend is
// active (see `lma::residual`), so no concurrent PJRT calls are issued
// by this crate today; the marker exists so `LmaFitCore` (which embeds
// `CovBackend`) stays `Sync` for the `ThreadCluster` execution backend.
unsafe impl Send for ArtifactLibrary {}
unsafe impl Sync for ArtifactLibrary {}

impl ArtifactLibrary {
    /// Default location relative to the repo root.
    pub fn default_dir() -> PathBuf {
        std::env::var("PGPR_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load the manifest and create the PJRT client. Fails with
    /// `Artifact` if the manifest is missing (callers treat that as
    /// "native path only").
    pub fn load(dir: &Path) -> Result<ArtifactLibrary> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            PgprError::Artifact(format!("manifest {manifest_path:?}: {e} (run `make artifacts`)"))
        })?;
        let j = Json::parse(&text)?;
        let mut entries = Vec::new();
        for item in j.req("artifacts")?.as_arr().unwrap_or(&[]) {
            entries.push(ArtifactEntry {
                name: item.req("name")?.as_str().unwrap_or_default().to_string(),
                file: item.req("file")?.as_str().unwrap_or_default().to_string(),
                n1: item.req("n1")?.as_usize().unwrap_or(0),
                n2: item.req("n2")?.as_usize().unwrap_or(0),
                d: item.req("d")?.as_usize().unwrap_or(0),
            });
        }
        if entries.is_empty() {
            return Err(PgprError::Artifact("manifest has no artifacts".into()));
        }
        let engine = PjrtEngine::cpu()?;
        Ok(ArtifactLibrary { dir: dir.to_path_buf(), engine, entries, cache: Mutex::new(HashMap::new()) })
    }

    /// Try the default directory; None if artifacts are not built.
    pub fn try_default() -> Option<ArtifactLibrary> {
        ArtifactLibrary::load(&Self::default_dir()).ok()
    }

    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    /// Smallest bucket of `name` that fits (n1, n2, d).
    fn pick_bucket(&self, name: &str, n1: usize, n2: usize, d: usize) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.name == name && e.n1 >= n1 && e.n2 >= n2 && e.d >= d)
            .min_by_key(|e| e.n1 * e.n2)
            .ok_or_else(|| {
                PgprError::Artifact(format!(
                    "no `{name}` bucket fits ({n1}, {n2}, d={d}); available: {:?}",
                    self.entries
                        .iter()
                        .filter(|e| e.name == name)
                        .map(|e| (e.n1, e.n2, e.d))
                        .collect::<Vec<_>>()
                ))
            })
    }

    /// Compiled executable for an entry, compiling lazily on first use.
    /// The compile happens under the cache lock (so one artifact is never
    /// compiled twice), but the returned `Arc` lets callers execute
    /// *outside* the lock — concurrent `ThreadCluster` rank tasks run
    /// their PJRT calls in parallel.
    fn executable(&self, entry: &ArtifactEntry) -> Result<Arc<PjrtExecutable>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(exe) = cache.get(&entry.file) {
            return Ok(exe.clone());
        }
        let exe = Arc::new(self.engine.compile_hlo_text(&self.dir.join(&entry.file), &entry.name)?);
        cache.insert(entry.file.clone(), exe.clone());
        Ok(exe)
    }

    /// Cross-covariance through the compiled Pallas kernel:
    /// K[i,j] = σ_s²·exp(−½‖x1_i − x2_j‖²) over **pre-scaled** inputs —
    /// the PJRT twin of `kernels::se_ard::cov_cross_scaled`.
    pub fn cov_cross_scaled(&self, s1: &Mat, s2: &Mat, sigma_s2: f64) -> Result<Mat> {
        let (n1, n2, d) = (s1.rows(), s2.rows(), s1.cols());
        if s2.cols() != d {
            return Err(PgprError::Shape("pjrt cov: dim mismatch".into()));
        }
        let entry = self.pick_bucket("cov_cross", n1, n2, d)?.clone();
        let exe = self.executable(&entry)?;

        // Pad inputs to the bucket shape (f32).
        let pad = |m: &Mat, rows: usize, cols: usize| -> Vec<f32> {
            let mut out = vec![0.0f32; rows * cols];
            for i in 0..m.rows() {
                for j in 0..m.cols() {
                    out[i * cols + j] = m.get(i, j) as f32;
                }
            }
            out
        };
        let x1 = pad(s1, entry.n1, entry.d);
        let x2 = pad(s2, entry.n2, entry.d);
        let sig = vec![sigma_s2 as f32];

        let out = exe.run_f32(&[
            (&x1, &[entry.n1, entry.d]),
            (&x2, &[entry.n2, entry.d]),
            (&sig, &[]),
        ])?;
        if out.len() != entry.n1 * entry.n2 {
            return Err(PgprError::Pjrt(format!(
                "cov_cross returned {} values, expected {}",
                out.len(),
                entry.n1 * entry.n2
            )));
        }
        // Unpad.
        let mut k = Mat::zeros(n1, n2);
        for i in 0..n1 {
            for j in 0..n2 {
                k.set(i, j, out[i * entry.n2 + j] as f64);
            }
        }
        Ok(k)
    }

    /// Gram accumulation acc + Vᵀ·V through the compiled `summary_gram`
    /// Pallas kernel (manifest entries carry (k, m, m) as (n1, n2, d)).
    /// Zero padding is exact: padded rows of V contribute nothing.
    pub fn summary_gram(&self, v: &Mat, acc: &Mat) -> Result<Mat> {
        let (k, m) = (v.rows(), v.cols());
        if acc.rows() != m || acc.cols() != m {
            return Err(PgprError::Shape("summary_gram: acc must be m×m".into()));
        }
        let entry = self.pick_bucket("summary_gram", k, m, m)?.clone();
        let exe = self.executable(&entry)?;
        let pad = |src: &Mat, rows: usize, cols: usize| -> Vec<f32> {
            let mut out = vec![0.0f32; rows * cols];
            for i in 0..src.rows() {
                for j in 0..src.cols() {
                    out[i * cols + j] = src.get(i, j) as f32;
                }
            }
            out
        };
        let vp = pad(v, entry.n1, entry.n2);
        let ap = pad(acc, entry.n2, entry.n2);
        let out = exe.run_f32(&[
            (&vp, &[entry.n1, entry.n2]),
            (&ap, &[entry.n2, entry.n2]),
        ])?;
        let mut g = Mat::zeros(m, m);
        for i in 0..m {
            for j in 0..m {
                g.set(i, j, out[i * entry.n2 + j] as f64);
            }
        }
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_manifest_is_artifact_error() {
        let r = ArtifactLibrary::load(Path::new("/nonexistent/dir"));
        assert!(matches!(r, Err(PgprError::Artifact(_))));
    }

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join("pgpr_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [{"name": "cov_cross", "file": "x.hlo.txt", "n1": 64, "n2": 64, "d": 8}]}"#,
        )
        .unwrap();
        // PJRT client creation may succeed; bucket selection is what we
        // check here.
        match ArtifactLibrary::load(&dir) {
            Ok(lib) => {
                assert_eq!(lib.entries().len(), 1);
                assert!(lib.pick_bucket("cov_cross", 32, 64, 8).is_ok());
                assert!(lib.pick_bucket("cov_cross", 65, 64, 8).is_err());
                assert!(lib.pick_bucket("other", 1, 1, 1).is_err());
            }
            Err(PgprError::Pjrt(_)) => { /* no PJRT plugin in this env */ }
            Err(e) => panic!("unexpected error: {e}"),
        }
        std::fs::remove_dir_all(dir).ok();
    }
}
